#!/usr/bin/env python3
"""Connection-scale drill for the evented HTTP front-end.

Opens N concurrent keep-alive connections against a running `lpdsvm serve
--io-model evented` instance, completes a healthz round-trip on every one,
re-checks a subset to prove the early connections are still alive, then
asserts from the outside what the event loop promises:

* the server's `/metrics` gauge shows all N connections open at once;
* the server process holds a small, connection-independent thread count
  (read from /proc/<pid>/status — a thread-per-connection design would
  show ~N threads here);
* every healthz round-trip answered 200.

Writes a JSON report (client-side latency percentiles plus the server's
shed/latency counters) for upload as a CI artifact.

Usage: evented_drill.py PORT CONNECTIONS SERVER_PID REPORT_PATH
"""

import json
import socket
import sys
import time

HOST = "127.0.0.1"
# Generous, connection-independent budget: engine workers + scoring pool
# + supervisor + the one event-loop thread + runtime slack. The point of
# the assertion is the gap to CONNECTIONS (4096), not the exact figure.
MAX_THREADS = 24

HEALTHZ = b"GET /healthz HTTP/1.1\r\nhost: drill\r\n\r\n"
METRICS = b"GET /metrics HTTP/1.1\r\nhost: drill\r\nconnection: close\r\n\r\n"


def request(sock, raw):
    """One request on a keep-alive socket -> (status, body bytes)."""
    sock.sendall(raw)
    buf = b""
    while b"\r\n\r\n" not in buf:
        chunk = sock.recv(65536)
        if not chunk:
            raise RuntimeError("server closed mid-headers")
        buf += chunk
    head, _, body = buf.partition(b"\r\n\r\n")
    status = int(head.split(None, 2)[1])
    length = 0
    for line in head.split(b"\r\n")[1:]:
        name, _, value = line.partition(b":")
        if name.strip().lower() == b"content-length":
            length = int(value.strip())
    while len(body) < length:
        chunk = sock.recv(65536)
        if not chunk:
            raise RuntimeError("server closed mid-body")
        body += chunk
    return status, body[:length]


def main():
    port = int(sys.argv[1])
    n_conns = int(sys.argv[2])
    server_pid = int(sys.argv[3])
    report_path = sys.argv[4]

    socks = []
    latencies = []
    t0 = time.time()
    for i in range(n_conns):
        s = socket.create_connection((HOST, port), timeout=30)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        q0 = time.time()
        status, _ = request(s, HEALTHZ)
        latencies.append(time.time() - q0)
        if status != 200:
            raise SystemExit(f"connection {i}: healthz answered {status}")
        socks.append(s)
    ramp_secs = time.time() - t0

    # Second round on a stride of survivors: the early connections must
    # still be live while thousands of later ones are open.
    for i in range(0, n_conns, 97):
        status, _ = request(socks[i], HEALTHZ)
        if status != 200:
            raise SystemExit(f"connection {i} died during the drill ({status})")

    # Scrape the gauge while every drill connection is still open.
    scrape = socket.create_connection((HOST, port), timeout=30)
    status, body = request(scrape, METRICS)
    if status != 200:
        raise SystemExit(f"metrics scrape answered {status}")
    metrics = json.loads(body)
    conn_open = metrics["conn_open"]
    if conn_open < n_conns:
        raise SystemExit(f"conn_open gauge {conn_open} < {n_conns} drill connections")

    threads = None
    with open(f"/proc/{server_pid}/status") as f:
        for line in f:
            if line.startswith("Threads:"):
                threads = int(line.split()[1])
    if threads is None:
        raise SystemExit("no Threads line in /proc status")
    if not 0 < threads <= MAX_THREADS:
        raise SystemExit(
            f"server holds {threads} threads for {n_conns} connections "
            f"(budget {MAX_THREADS}) — connection plane is not evented"
        )

    latencies.sort()
    report = {
        "connections": n_conns,
        "server_threads": threads,
        "thread_budget": MAX_THREADS,
        "conn_open_gauge": conn_open,
        "ramp_secs": round(ramp_secs, 3),
        "healthz_ms": {
            "p50": round(latencies[len(latencies) // 2] * 1e3, 3),
            "p99": round(latencies[(len(latencies) * 99) // 100 - 1] * 1e3, 3),
            "max": round(latencies[-1] * 1e3, 3),
        },
        "server_latency_us": metrics.get("latency_us"),
        "shed": {
            "rejected_full": metrics.get("rejected_full"),
            "shed_expired": metrics.get("shed_expired"),
        },
        "conn_idle_reaped": metrics.get("conn_idle_reaped"),
    }
    with open(report_path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    for s in socks:
        s.close()


if __name__ == "__main__":
    main()
