"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the shape space (tile-aligned, as the kernels require —
the Rust runtime guarantees alignment by padding) and the parameter space
(gamma, value scale). assert_allclose against ref.py is the core
correctness signal for the accelerator path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.matmul import matmul_pallas
from compile.kernels.rbf_gram import rbf_gram_pallas
from compile.kernels.ref import matmul_ref, rbf_gram_ref, stage1_chunk_ref
from compile.model import stage1_chunk, stage1_chunk_xla

TILE = 128


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------- rbf_gram

@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 3),
    bt=st.integers(1, 2),
    p=st.sampled_from([8, 32, 100, 256]),
    gamma=st.floats(1e-4, 2.0),
    scale=st.floats(0.1, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_gram_matches_ref(mt, bt, p, gamma, scale, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, mt * TILE, p, scale=scale)
    l = rand(rng, bt * TILE, p, scale=scale)
    got = rbf_gram_pallas(x, l, gamma)
    want = rbf_gram_ref(x, l, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_rbf_gram_self_distance_is_one():
    rng = np.random.default_rng(1)
    x = rand(rng, TILE, 16)
    k = rbf_gram_pallas(x, x, 0.5)
    # f32 cancellation in ||x||²+||x||²−2⟨x,x⟩ leaves ~1e-4 residuals.
    np.testing.assert_allclose(np.diag(np.asarray(k)), 1.0, atol=1e-3)


def test_rbf_gram_values_in_unit_interval():
    rng = np.random.default_rng(2)
    x = rand(rng, TILE, 8, scale=5.0)
    l = rand(rng, TILE, 8, scale=5.0)
    k = np.asarray(rbf_gram_pallas(x, l, 0.3))
    assert k.min() >= 0.0 and k.max() <= 1.0 + 1e-6


def test_rbf_gram_zero_padding_rows_are_benign():
    """Zero-padded landmark rows produce k(x, 0) != 0 but the whitening
    multiply cancels them — verified at the stage1 level below; here we
    check padded DATA rows produce finite values only."""
    rng = np.random.default_rng(3)
    x = np.zeros((TILE, 8), np.float32)
    x[:7] = rng.normal(size=(7, 8))
    l = rand(rng, TILE, 8)
    k = np.asarray(rbf_gram_pallas(jnp.asarray(x), l, 0.2))
    assert np.isfinite(k).all()


def test_rbf_gram_rejects_misaligned_shapes():
    rng = np.random.default_rng(4)
    x = rand(rng, 100, 8)  # not a multiple of 128
    l = rand(rng, TILE, 8)
    with pytest.raises(AssertionError):
        rbf_gram_pallas(x, l, 0.1)


# ------------------------------------------------------------------ matmul

@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 3),
    k=st.sampled_from([8, 64, 200, 512]),
    nt=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(mt, k, nt, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, mt * TILE, k)
    b = rand(rng, k, nt * TILE)
    got = matmul_pallas(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    rng = np.random.default_rng(5)
    a = rand(rng, TILE, TILE)
    eye = jnp.eye(TILE, dtype=jnp.float32)
    np.testing.assert_allclose(matmul_pallas(a, eye), a, atol=1e-6)


# ----------------------------------------------------------------- stage 1

@settings(max_examples=10, deadline=None)
@given(
    p=st.sampled_from([8, 32, 123]),
    gamma=st.floats(1e-3, 1.0),
    rank=st.integers(1, TILE),
    seed=st.integers(0, 2**31 - 1),
)
def test_stage1_chunk_matches_ref(p, gamma, rank, seed):
    rng = np.random.default_rng(seed)
    m, b = 2 * TILE, TILE
    x = rand(rng, m, p)
    l = rand(rng, b, p)
    # Whitening map with only `rank` live columns (rest zero), as the Rust
    # runtime pads it.
    w = np.zeros((b, b), np.float32)
    w[:, :rank] = rng.normal(size=(b, rank)) * 0.1
    g = jnp.asarray([[gamma]], jnp.float32)
    got = stage1_chunk(x, l, jnp.asarray(w), g)
    want = stage1_chunk_ref(x, l, jnp.asarray(w), gamma)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # Dead columns stay exactly zero.
    np.testing.assert_array_equal(np.asarray(got)[:, rank:], 0.0)


def test_stage1_padded_landmarks_cancel():
    """The padding-exactness contract used by rust/src/runtime/accel.rs:
    zero landmark rows (whose whitening rows are zero) must not affect G."""
    rng = np.random.default_rng(6)
    p, b_real, m = 16, 40, TILE
    x = rand(rng, m, p)
    l_real = np.asarray(rng.normal(size=(b_real, p)), np.float32)
    w_real = np.asarray(rng.normal(size=(b_real, b_real)), np.float32)
    gamma = 0.17

    l_pad = np.zeros((TILE, p), np.float32)
    l_pad[:b_real] = l_real
    w_pad = np.zeros((TILE, TILE), np.float32)
    w_pad[:b_real, :b_real] = w_real

    got = np.asarray(
        stage1_chunk(
            jnp.asarray(x),
            jnp.asarray(l_pad),
            jnp.asarray(w_pad),
            jnp.asarray([[gamma]], jnp.float32),
        )
    )
    want = np.asarray(
        stage1_chunk_ref(jnp.asarray(x), jnp.asarray(l_real), jnp.asarray(w_real), gamma)
    )
    np.testing.assert_allclose(got[:, :b_real], want, rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(got[:, b_real:], 0.0)


def test_pallas_and_xla_graphs_agree():
    rng = np.random.default_rng(7)
    x = rand(rng, TILE, 32)
    l = rand(rng, TILE, 32)
    w = rand(rng, TILE, TILE, scale=0.1)
    g = jnp.asarray([[0.05]], jnp.float32)
    a = stage1_chunk(x, l, w, g)
    b = stage1_chunk_xla(x, l, w, g)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
