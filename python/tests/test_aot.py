"""AOT pipeline tests: lowering produces loadable HLO text with the
expected interface, and the lowered computation is numerically identical
to the traced one when re-executed through the XLA client."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import CHUNK_M, lower_stage1
from compile.kernels.ref import stage1_chunk_ref


def test_lowering_produces_hlo_text():
    text = lower_stage1(CHUNK_M, 128, 32)
    assert "HloModule" in text
    # Static shapes must appear in the entry computation.
    assert f"f32[{CHUNK_M},32]" in text
    assert "f32[128,32]" in text
    assert "f32[128,128]" in text


def test_lowering_has_no_custom_calls():
    """The CPU PJRT plugin can only run pure HLO: interpret-mode Pallas
    must not leave Mosaic custom-calls behind, and nothing may lower to
    lapack/ducc FFI calls."""
    text = lower_stage1(CHUNK_M, 128, 32)
    assert "custom-call" not in text, "artifact contains custom-calls"


def test_hlo_text_parses_back():
    """The emitted text must round-trip through XLA's HLO parser — the
    exact entry point the Rust runtime uses (HloModuleProto::from_text_file
    through the C API). Numerical equivalence of the parsed program is
    covered by the Rust integration test `accel_matches_native_g`."""
    b, p = 128, 32
    text = lower_stage1(CHUNK_M, b, p)
    module = xc._xla.hlo_module_from_text(text)
    text2 = module.to_string()
    assert "HloModule" in text2
    # Same entry signature after the round-trip.
    for shape in (f"f32[{CHUNK_M},{p}]", f"f32[{b},{p}]", f"f32[{b},{b}]", "f32[1,1]"):
        assert shape in text2, f"{shape} lost in round-trip"


def test_manifest_matches_emitted_files(tmp_path):
    """Run the module CLI end-to-end into a temp dir."""
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        timeout=600,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) >= 4
    for a in manifest["artifacts"]:
        f = out / a["file"]
        assert f.exists(), f"missing {a['file']}"
        assert a["m"] == CHUNK_M
        text = f.read_text()
        assert "HloModule" in text
        assert "custom-call" not in text
