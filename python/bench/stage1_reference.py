#!/usr/bin/env python3
"""NumPy reference mirror of `rust/benches/stage1_throughput.rs`.

Runs the same stage-1 pipeline shape — landmark Gram + eigendecomposition,
then the chunked `G = kernel_block(X, L) @ W` assembly — with the same
row-band threading strategy (contiguous bands of output rows per worker;
NumPy releases the GIL inside its kernels, so bands genuinely run in
parallel). BLAS-internal threading is pinned to 1 so the sweep measures
*our* banding, not OpenBLAS's.

This exists for environments that can run Python but not `cargo bench`
(e.g. the container this repo is grown in): it produces a
`BENCH_stage1.json` with the same schema so the perf trajectory file can
be seeded/checked anywhere. The Rust bench overwrites it with native
numbers whenever it runs — treat those as authoritative.

    python3 python/bench/stage1_reference.py [--smoke] [--out PATH]
"""

import argparse
import json
import os
import sys
import threading
import time

# Pin BLAS threading *before* importing numpy so t=1 is truly serial.
for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(var, "1")

import numpy as np  # noqa: E402


def kernel_block(x, x_sq, lm, lm_sq, gamma):
    """Gaussian block exp(-gamma * ||x - l||^2) via the GEMM identity."""
    dots = x @ lm.T
    d2 = np.maximum(x_sq[:, None] + lm_sq[None, :] - 2.0 * dots, 0.0)
    return np.exp(-gamma * d2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_stage1.json")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    n, p, budget, chunk = (3_000, 48, 160, 256) if args.smoke else (24_000, 96, 640, 512)
    cores = os.cpu_count() or 1
    x = np.random.default_rng(args.seed).standard_normal((n, p)).astype(np.float32)
    x_sq = (x * x).sum(axis=1)
    gamma = np.float32(0.5 / p)

    results = []
    serial_g = None
    serial_secs = None
    sweep = sorted(set([1, 2, 4, 8, cores]))
    for t in sweep:
        t0 = time.perf_counter()
        # Fresh generator per sweep point: same landmarks for every thread
        # count (mirrors the fixed cfg.seed in the Rust bench).
        rng = np.random.default_rng(args.seed + 1)
        lm = x[np.sort(rng.choice(n, budget, replace=False))]
        lm_sq = (lm * lm).sum(axis=1)
        kbb = kernel_block(lm, lm_sq, lm, lm_sq, gamma).astype(np.float64)
        evals, evecs = np.linalg.eigh(kbb)
        keep = evals > evals.max() * 1e-6
        rank = int(keep.sum())
        w = (evecs[:, keep] / np.sqrt(evals[keep])).astype(np.float32)
        prep = time.perf_counter() - t0

        g = np.zeros((n, rank), dtype=np.float32)
        # Band boundaries are chunk-aligned so every chunk is the exact
        # same slice at every thread count (BLAS rounding depends on the
        # slice shape) — mirroring the bit-identical contract of the Rust
        # row-band kernel.
        chunks = [(cs, min(cs + chunk, n)) for cs in range(0, n, chunk)]

        def band(work):
            for cs, ce in work:
                k = kernel_block(x[cs:ce], x_sq[cs:ce], lm, lm_sq, gamma)
                g[cs:ce] = k @ w

        t0 = time.perf_counter()
        if t == 1:
            band(chunks)
        else:
            bs = -(-len(chunks) // t)
            workers = [
                threading.Thread(target=band, args=(chunks[i * bs : (i + 1) * bs],))
                for i in range(t)
                if i * bs < len(chunks)
            ]
            for wk in workers:
                wk.start()
            for wk in workers:
                wk.join()
        mg = time.perf_counter() - t0

        if serial_g is None:
            serial_g, serial_secs = g, mg
        elif not np.array_equal(serial_g, g):
            print(f"FATAL: t={t} diverged from serial", file=sys.stderr)
            return 1

        flops = n * 2.0 * budget * (p + rank)
        gflops = flops / max(mg, 1e-12) / 1e9
        speedup = serial_secs / max(mg, 1e-12)
        results.append(
            {
                "threads": t,
                "preparation_s": round(prep, 6),
                "matrix_g_s": round(mg, 6),
                "gflops": round(gflops, 3),
                "speedup_vs_1thread": round(speedup, 3),
                "rank": rank,
            }
        )
        print(
            f"threads={t:>2}  prep={prep:.3f}s  matrix_g={mg:.3f}s  "
            f"{gflops:.2f} GFLOP/s  {speedup:.2f}x"
        )

    doc = {
        "bench": "stage1_throughput",
        "source": "python/bench/stage1_reference.py (NumPy mirror; no Rust "
        "toolchain in the build container — `cargo bench --bench "
        "stage1_throughput` overwrites this with native numbers)",
        "smoke": args.smoke,
        "dataset": {
            "n": n,
            "p": p,
            "classes": 6,
            "budget": budget,
            "chunk": chunk,
            "kernel": "gaussian",
            "seed": args.seed,
        },
        "host_cores": cores,
        "results": results,
        "best_speedup_vs_1thread": max(r["speedup_vs_1thread"] for r in results),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
