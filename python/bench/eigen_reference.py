#!/usr/bin/env python3
"""NumPy reference mirror of `rust/benches/eigen_sweep.rs`.

Implements both Jacobi orderings the Rust crate ships — the serial cyclic
sweep and the round-robin tournament ordering behind `sym_eig_threads` —
on the same Gaussian `K_BB` workload. It validates the tournament
ordering (spectrum parity with the cyclic ordering, determinism across
thread counts) and records serial-vs-tournament seconds; by default the
tournament runs single-threaded because CPython's GIL serialises the
rotation bookkeeping and turns the per-round fan-out into a slowdown —
`--sweep-threads` opts into that (honest but misleading) sweep, which
dispatches each round's disjoint column/row groups onto one *persistent*
`ThreadPoolExecutor` (mirroring the Rust worker pool; spawning fresh
threads per phase measured another 2× worse, the exact pathology the
persistent pool removes). Treat the Rust bench as authoritative for
thread scaling. BLAS threading is pinned to 1.

This exists for environments that can run Python but not `cargo bench`
(e.g. the container this repo is grown in): it produces a
`BENCH_eigen.json` with the same schema so the perf trajectory file can be
seeded/checked anywhere. The Rust bench overwrites it with native numbers
whenever it runs — treat those as authoritative.

    python3 python/bench/eigen_reference.py [--smoke] [--out PATH]
"""

import argparse
import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(var, "1")

import numpy as np  # noqa: E402


def gaussian_kbb(rng, b, p, gamma):
    x = rng.standard_normal((b, p)).astype(np.float32)
    sq = (x * x).sum(axis=1)
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    k = np.exp(-gamma * d2).astype(np.float64)
    return (k + k.T) / 2.0


def rotations(m, pairs, thresh):
    """Rotation params for the round's disjoint pairs (Golub & Van Loan)."""
    n = m.shape[0]
    rots = []
    for p, q in pairs:
        apq = m[p, q]
        if abs(apq) <= thresh / n:
            continue
        theta = (m[q, q] - m[p, p]) / (2.0 * apq)
        t = 1.0 / (theta + np.sqrt(1.0 + theta * theta)) if theta >= 0 else -1.0 / (
            -theta + np.sqrt(1.0 + theta * theta)
        )
        c = 1.0 / np.sqrt(1.0 + t * t)
        rots.append((p, q, c, t * c))
    return rots


def round_pairs(players, r):
    wheel = players - 1
    pairs = [(min(r % wheel, players - 1), players - 1)]
    for i in range(1, players // 2):
        x, y = (r + i) % wheel, (r + wheel - i) % wheel
        pairs.append((min(x, y), max(x, y)))
    return pairs


def apply_cols(m, rots):
    ps = [r[0] for r in rots]
    qs = [r[1] for r in rots]
    c = np.array([r[2] for r in rots])
    s = np.array([r[3] for r in rots])
    colp, colq = m[:, ps].copy(), m[:, qs].copy()
    m[:, ps] = c * colp - s * colq
    m[:, qs] = s * colp + c * colq


def apply_rows(m, rots):
    ps = [r[0] for r in rots]
    qs = [r[1] for r in rots]
    c = np.array([r[2] for r in rots])[:, None]
    s = np.array([r[3] for r in rots])[:, None]
    rowp, rowq = m[ps, :].copy(), m[qs, :].copy()
    m[ps, :] = c * rowp - s * rowq
    m[qs, :] = s * rowp + c * rowq


def split(rots, t):
    bs = -(-len(rots) // t)
    return [rots[i * bs : (i + 1) * bs] for i in range(t) if i * bs < len(rots)]


def phase(fn, m, rots, t, pool):
    groups = split(rots, t)
    if pool is None or len(groups) <= 1:
        for g in groups:
            fn(m, g)
        return
    list(pool.map(lambda g: fn(m, g), groups))


def tournament_jacobi(a, max_sweeps, tol, t, pool):
    n = a.shape[0]
    m = a.copy()
    v = np.eye(n)
    thresh = tol * max(np.sqrt((m * m).sum()), np.finfo(np.float64).tiny)
    players = n + (n % 2)
    for _ in range(max_sweeps):
        off = np.sqrt(2.0 * (np.triu(m, 1) ** 2).sum())
        if off <= thresh:
            break
        for r in range(players - 1):
            pairs = [(p, q) for p, q in round_pairs(players, r) if q < n]
            rots = rotations(m, pairs, thresh)
            if not rots:
                continue
            phase(apply_cols, m, rots, t, pool)
            phase(apply_rows, m, rots, t, pool)
            phase(apply_cols, v, rots, t, pool)
    return np.sort(np.diag(m))[::-1], v


def cyclic_jacobi(a, max_sweeps, tol):
    n = a.shape[0]
    m = a.copy()
    thresh = tol * max(np.sqrt((m * m).sum()), np.finfo(np.float64).tiny)
    for _ in range(max_sweeps):
        off = np.sqrt(2.0 * (np.triu(m, 1) ** 2).sum())
        if off <= thresh:
            break
        for p in range(n):
            for q in range(p + 1, n):
                rots = rotations(m, [(p, q)], thresh)
                if not rots:
                    continue
                apply_cols(m, rots)
                apply_rows(m, rots)
    return np.sort(np.diag(m))[::-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sweep-threads", action="store_true")
    ap.add_argument("--out", default="BENCH_eigen.json")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    b, p = (160, 32) if args.smoke else (640, 64)
    cores = os.cpu_count() or 1
    kbb = gaussian_kbb(np.random.default_rng(args.seed), b, p, 0.5 / p)

    t0 = time.perf_counter()
    serial_vals = cyclic_jacobi(kbb, 40, 1e-12)
    serial_secs = time.perf_counter() - t0
    lmax = max(serial_vals[0], 1e-30)
    print(f"serial cyclic: {serial_secs:.3f}s (B={b})")

    results = [
        {
            "solver": "sym_eig",
            "threads": 1,
            "secs": round(serial_secs, 6),
            "speedup_vs_serial": 1.0,
        }
    ]
    best = 1.0
    reference = None
    sweep = sorted(set([1, 2, 4, 8, cores])) if args.sweep_threads else [1]
    for t in sweep:
        pool = ThreadPoolExecutor(max_workers=t) if t > 1 else None
        t0 = time.perf_counter()
        vals, _ = tournament_jacobi(kbb, 40, 1e-12, t, pool)
        secs = time.perf_counter() - t0
        if pool is not None:
            pool.shutdown()
        dl = float(np.abs(vals - serial_vals).max())
        if dl > 1e-6 * lmax:
            print(f"FATAL: t={t} spectrum drift {dl}", file=sys.stderr)
            return 1
        if reference is None:
            reference = vals
        elif not np.array_equal(reference, vals):
            print(f"FATAL: t={t} nondeterministic", file=sys.stderr)
            return 1
        speedup = serial_secs / max(secs, 1e-12)
        best = max(best, speedup)
        results.append(
            {
                "solver": "sym_eig_threads",
                "threads": t,
                "secs": round(secs, 6),
                "speedup_vs_serial": round(speedup, 3),
                "max_abs_dlambda_rel": float(dl / lmax),
            }
        )
        print(f"tournament t={t}: {secs:.3f}s  {speedup:.2f}x  |Δλ|/λmax={dl / lmax:.2e}")

    doc = {
        "bench": "eigen_sweep",
        "source": "python/bench/eigen_reference.py (NumPy mirror; no Rust "
        "toolchain in the build container — `cargo bench --bench eigen_sweep` "
        "overwrites this with native numbers)",
        "smoke": args.smoke,
        "matrix": {"b": b, "p": p, "kernel": "gaussian", "seed": args.seed},
        "host_cores": cores,
        "results": results,
        "best_speedup_vs_serial": round(best, 3),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
