"""Tiled matmul Pallas kernel — the whitening projection ``K @ W``.

Grid over (m/TM, n/TN) output tiles with the contraction dimension kept
fully in VMEM (k = B ≤ 512 per artifact variant, so a (128, 512) K-tile
plus a (512, 128) W-tile is ≈ 512 KiB — small enough to double-buffer).
A k-blocked accumulator variant is unnecessary at these shapes; DESIGN.md
§Perf records the VMEM budget per variant.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a, b, *, interpret=True):
    """a (m, k) @ b (k, n) with m, n multiples of the 128-tile."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction dims differ: {k} vs {k2}"
    assert m % TILE_M == 0, f"m={m} not a multiple of {TILE_M}"
    assert n % TILE_N == 0, f"n={n} not a multiple of {TILE_N}"
    grid = (m // TILE_M, n // TILE_N)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TILE_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        interpret=interpret,
    )(a, b)
