"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel must match its oracle to float32 tolerance over the
hypothesis-swept shape/dtype space (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def rbf_gram_ref(x, landmarks, gamma):
    """K[i, j] = exp(-gamma * ||x_i - l_j||^2), computed via the Gram trick
    (one matmul + rank-1 norm corrections), matching the paper's batch
    kernel evaluation."""
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)  # (m, 1)
    l_sq = jnp.sum(landmarks * landmarks, axis=1)[None, :]  # (1, b)
    d2 = x_sq + l_sq - 2.0 * (x @ landmarks.T)
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-gamma * d2)


def matmul_ref(a, b):
    """Plain dense matmul."""
    return a @ b


def stage1_chunk_ref(x, landmarks, whiten, gamma):
    """G_chunk = K(x, L) @ W — the full stage-1 chunk computation."""
    return rbf_gram_ref(x, landmarks, gamma) @ whiten
