"""Layer-1 Pallas kernels: the stage-1 compute hot-spots.

`rbf_gram`   — tiled Gaussian Gram-block kernel (the batch kernel
               evaluation the paper runs with custom CUDA kernels).
`matmul`     — tiled matmul used for the whitening projection `K · W`.
`ref`        — pure-jnp oracles for pytest/hypothesis correctness checks.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is both the
correctness path and the artifact path on this testbed (DESIGN.md
§Hardware-Adaptation).
"""

from compile.kernels.matmul import matmul_pallas
from compile.kernels.rbf_gram import rbf_gram_pallas

__all__ = ["matmul_pallas", "rbf_gram_pallas"]
