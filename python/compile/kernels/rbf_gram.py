"""Tiled Gaussian Gram-block Pallas kernel.

Computes ``K[i, j] = exp(-gamma * ||x_i - l_j||^2)`` for a data chunk
``x (m, p)`` against the landmark matrix ``l (b, p)`` — the stage-1
workhorse that the paper implements as custom CUDA kernels over cuBLAS
GEMM tiles.

TPU adaptation of the paper's GPU design (DESIGN.md §Hardware-Adaptation):

* the CUDA threadblock tiling becomes a Pallas grid over (m/TM, b/TB)
  output tiles with BlockSpec expressing the HBM→VMEM schedule;
* the inner product matrix is computed on the MXU via ``jnp.dot`` over
  full-``p`` VMEM tiles (p ≤ 2048 per artifact variant ⇒ X-tile + L-tile
  ≈ 2×128×2048×4 B = 2 MiB ≪ 16 MiB VMEM, leaving room for double
  buffering);
* the ``||x||² + ||l||² − 2⟨x,l⟩ → exp`` epilogue is fused into the same
  tile, so the distance matrix never round-trips through HBM (the paper's
  motivation for custom kernels instead of plain cuBLAS + elementwise).

Arithmetic intensity per output tile: 2·TM·TB·p FLOPs for
(TM + TB)·p·4 bytes of input traffic ⇒ ≈ 2·128·p/(256·4·p/128) ≈ 64
FLOP/byte at TM = TB = 128 — compute-bound on the MXU, matching the
paper's observation that stage 1 saturates the accelerator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles.
TILE_M = 128
TILE_B = 128


def _rbf_gram_kernel(x_ref, l_ref, gamma_ref, o_ref):
    """One (TILE_M, TILE_B) output tile.

    x_ref:     (TILE_M, p) VMEM tile of the data chunk
    l_ref:     (TILE_B, p) VMEM tile of the landmarks
    gamma_ref: (1, 1) scalar
    o_ref:     (TILE_M, TILE_B) output tile
    """
    x = x_ref[...]
    l = l_ref[...]
    gamma = gamma_ref[0, 0]
    # MXU matmul in f32 (bf16 inputs would halve traffic; f32 keeps the
    # CPU-interpret numerics aligned with the rust native path).
    dots = jax.lax.dot_general(
        x, l, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)
    l_sq = jnp.sum(l * l, axis=1)[None, :]
    d2 = jnp.maximum(x_sq + l_sq - 2.0 * dots, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rbf_gram_pallas(x, landmarks, gamma, *, interpret=True):
    """Gram block via the tiled Pallas kernel.

    x:         (m, p) f32, m divisible by TILE_M (callers pad)
    landmarks: (b, p) f32, b divisible by TILE_B
    gamma:     (1, 1) f32
    returns    (m, b) f32
    """
    m, p = x.shape
    b, p2 = landmarks.shape
    assert p == p2, f"feature dims differ: {p} vs {p2}"
    assert m % TILE_M == 0, f"m={m} not a multiple of {TILE_M}"
    assert b % TILE_B == 0, f"b={b} not a multiple of {TILE_B}"
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (m // TILE_M, b // TILE_B)
    return pl.pallas_call(
        _rbf_gram_kernel,
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.float32),
        grid=grid,
        in_specs=[
            # X tile: row block i, all of p.
            pl.BlockSpec((TILE_M, p), lambda i, j: (i, 0)),
            # L tile: column block j, all of p.
            pl.BlockSpec((TILE_B, p), lambda i, j: (j, 0)),
            # gamma broadcast to every tile.
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_B), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, landmarks, gamma)
