"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Never imported at runtime — `make artifacts` runs `compile.aot` once and
the Rust binary consumes only the emitted HLO text under `artifacts/`.
"""
