"""AOT lowering: JAX+Pallas stage-1 graph → HLO text artifacts.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact per (m, b, p) shape variant; the Rust runtime picks the
smallest fitting variant and zero-pads (rust/src/runtime/accel.rs).
``manifest.json`` indexes the emitted files.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import stage1_chunk, stage1_chunk_xla

# Shape menu. m is the chunk height (one MXU tile column's worth of rows);
# b covers the scaled budgets the benches use; p covers the paper datasets'
# feature dims after scaling (Adult 123 → 128, SUSY 18 → 32, MNIST 784 →
# 1024, Epsilon 2000 / scaled-ImageNet ≤ 2508 → 2560).
CHUNK_M = 256
B_VARIANTS = (128, 512)
P_VARIANTS = (32, 128, 1024, 2560)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the rust
    side's to_tuple1 unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage1(m: int, b: int, p: int, use_pallas: bool = True) -> str:
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((m, p), f32)
    l = jax.ShapeDtypeStruct((b, p), f32)
    w = jax.ShapeDtypeStruct((b, b), f32)
    gamma = jax.ShapeDtypeStruct((1, 1), f32)
    fn = stage1_chunk if use_pallas else stage1_chunk_xla
    lowered = jax.jit(lambda *a: (fn(*a),)).lower(x, l, w, gamma)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the plain-XLA reference graph instead of the Pallas kernels",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    artifacts = []
    for b in B_VARIANTS:
        for p in P_VARIANTS:
            name = f"stage1_m{CHUNK_M}_b{b}_p{p}"
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out, fname)
            text = lower_stage1(CHUNK_M, b, p, use_pallas=not args.no_pallas)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            artifacts.append(
                {
                    "name": name,
                    "file": fname,
                    "m": CHUNK_M,
                    "b": b,
                    "p": p,
                    "sha256_16": digest,
                    "pallas": not args.no_pallas,
                }
            )
            print(f"lowered {name}: {len(text)} chars (sha {digest})", file=sys.stderr)

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "chunk_m": CHUNK_M,
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
