"""Layer-2 JAX graph: the stage-1 chunk computation.

``stage1_chunk(x, l, w, gamma)`` = ``rbf_gram(x, l, gamma) @ w`` — one
fused graph per chunk, calling both L1 Pallas kernels, so the distance
matrix and the Gram block live entirely on-device and only ``G_chunk``
returns to the host (mirroring the paper's GPU stage 1, where kernel
evaluation, whitening and the matrix product are chained on the GPU).

Shapes are static per artifact variant (m, b, p); the Rust runtime
zero-pads inputs up to the variant (see rust/src/runtime/accel.rs for the
exactness argument) and gamma arrives as a (1, 1) array so ONE artifact
serves every kernel bandwidth in a grid search.
"""

import jax.numpy as jnp

from compile.kernels.matmul import matmul_pallas
from compile.kernels.rbf_gram import rbf_gram_pallas


def stage1_chunk(x, landmarks, whiten, gamma, *, interpret=True):
    """G_chunk = K(x, L) @ W.

    x:         (m, p) data chunk (zero-padded rows allowed)
    landmarks: (b, p) landmark matrix (zero-padded rows allowed — their
               whitening rows are zero, so they cancel)
    whiten:    (b, b) whitening map, rank columns live in the left block
    gamma:     (1, 1) Gaussian bandwidth
    returns    (m, b) G chunk (tuple-wrapped by the AOT lowering)
    """
    k_block = rbf_gram_pallas(x, landmarks, gamma, interpret=interpret)
    return matmul_pallas(k_block, whiten, interpret=interpret)


def stage1_chunk_xla(x, landmarks, whiten, gamma):
    """Reference L2 graph built from plain jnp ops (no Pallas) — used by
    tests and by the `--no-pallas` AOT escape hatch to compare lowered
    HLO size and structure."""
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)
    l_sq = jnp.sum(landmarks * landmarks, axis=1)[None, :]
    d2 = jnp.maximum(x_sq + l_sq - 2.0 * (x @ landmarks.T), 0.0)
    k_block = jnp.exp(-jnp.reshape(gamma, ()) * d2)
    return k_block @ whiten
