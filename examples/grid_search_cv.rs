//! Hyperparameter tuning the paper's way: (C, γ) grid search with 5-fold
//! cross-validation, where stage 1 runs once per γ and solvers along the
//! C path are warm-started — the machinery behind table 3.
//!
//!     cargo run --release --example grid_search_cv

use lpdsvm::prelude::*;
use lpdsvm::report::Table;

fn main() -> anyhow::Result<()> {
    let spec = PaperDataset::Susy.spec(0.0005, 42); // SUSY-analogue, small
    let data = spec.synth.generate();
    println!("dataset: {} points, {} features", data.len(), data.dim());

    let base = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: 64,
            ..Default::default()
        },
        solver: SolverOptions::default(),
        ..Default::default()
    };
    let grid = GridConfig {
        c_values: (0..6).map(|i| 4f64.powi(i)).collect(),
        gamma_values: (-1..=1).map(|i| spec.gamma * 4f64.powi(i)).collect(),
        cv_folds: 5,
        seed: 42,
        warm_start: true,
    };

    let result = grid_search(&data, &base, &grid)?;

    let mut t = Table::new("grid results", &["gamma", "C", "cv error %"]);
    for p in &result.points {
        t.row(&[
            format!("{:.3e}", p.gamma),
            format!("{}", p.c),
            Table::pct(p.cv.mean_error),
        ]);
    }
    t.print();
    println!(
        "best: C={} gamma={:.3e} → {:.2}% CV error",
        result.best_c,
        result.best_gamma,
        result.best_error * 100.0
    );
    println!(
        "{} binary problems in {:.2}s — {:.4}s per problem (stage 1 amortised: {:.2}s total, once per γ)",
        result.n_binary_problems,
        result.total_secs,
        result.secs_per_problem(),
        result.stage1_secs
    );

    // Retrain at the tuned point on all data.
    let mut final_cfg = base.clone();
    final_cfg.kernel = base.kernel.with_gamma(result.best_gamma);
    final_cfg.solver.c = result.best_c;
    let model = train(&data, &final_cfg)?;
    println!(
        "final model trained at tuned parameters: train error {:.2}%",
        model.error_rate(&data.x, &data.labels)? * 100.0
    );
    Ok(())
}
