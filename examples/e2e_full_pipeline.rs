//! End-to-end system driver — proves every layer composes on a real
//! workload (recorded in EXPERIMENTS.md §E2E):
//!
//!   L1/L2 (build time)  Pallas rbf_gram + matmul kernels, lowered by
//!                       python/compile/aot.py into artifacts/*.hlo.txt
//!   Runtime             rust loads the HLO text, compiles it on the PJRT
//!                       CPU client, and runs stage 1 through it
//!   L3                  landmark selection, Jacobi eigh, dual CD with
//!                       shrinking, OVO multiclass, prediction, metrics
//!
//! Workload: an MNIST-8M-analogue (10 classes) — train with BOTH backends,
//! verify they agree numerically, report error + timing breakdown.
//!
//!     cargo run --release --example e2e_full_pipeline

use lpdsvm::model::io as model_io;
use lpdsvm::prelude::*;
use lpdsvm::report::Table;
use lpdsvm::runtime::{AccelBackend, Runtime};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::var("LPDSVM_EXAMPLE_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0008);
    println!("=== LPD-SVM end-to-end driver ===\n");

    // ---------- workload ----------
    let spec = PaperDataset::Mnist8m.spec(scale, 42);
    let data = spec.synth.generate();
    let mut rng = Rng::new(3);
    let (train_set, test_set) = data.split(0.2, &mut rng);
    println!(
        "workload: MNIST-8M analogue — {} train / {} test, p={}, {} classes, {} OVO pairs",
        train_set.len(),
        test_set.len(),
        data.dim(),
        data.n_classes,
        data.n_classes * (data.n_classes - 1) / 2
    );

    let cfg = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: spec.budget.min(512), // largest artifact variant
            chunk: 256,
            ..Default::default()
        },
        solver: SolverOptions {
            c: spec.c,
            ..Default::default()
        },
        ..Default::default()
    };

    // ---------- native backend ----------
    let mut native_clock = StageClock::new();
    let model_native = lpdsvm::coordinator::train::train_with_backend(
        &train_set,
        &cfg,
        &NativeBackend::default(),
        &mut native_clock,
    )?;
    let err_native = model_native.error_rate(&test_set.x, &test_set.labels)?;

    // ---------- PJRT (AOT JAX+Pallas artifact) backend ----------
    let runtime = Runtime::load(&Runtime::default_dir())?;
    println!(
        "\nPJRT runtime: platform '{}', {} artifacts",
        runtime.platform(),
        runtime.artifacts().len()
    );
    let accel = AccelBackend::new(&runtime);
    let mut accel_clock = StageClock::new();
    let model_accel = lpdsvm::coordinator::train::train_with_backend(
        &train_set,
        &cfg,
        &accel,
        &mut accel_clock,
    )?;
    let err_accel = model_accel.error_rate(&test_set.x, &test_set.labels)?;

    // ---------- cross-layer verification ----------
    let g_diff = model_native.factor.g.max_abs_diff(&model_accel.factor.g);
    anyhow::ensure!(
        g_diff < 1e-2,
        "backends disagree on G (max diff {g_diff})"
    );
    println!("\ncross-backend check: max |G_native − G_pjrt| = {g_diff:.2e} ✓");

    // ---------- report ----------
    let mut t = Table::new(
        "e2e stage breakdown (seconds)",
        &["stage", "native", "pjrt"],
    );
    for stage in ["preparation", "matrix_g", "linear_train"] {
        t.row(&[
            stage.into(),
            Table::secs(native_clock.secs(stage)),
            Table::secs(accel_clock.secs(stage)),
        ]);
    }
    t.print();
    println!(
        "test error: native {:.2}%  pjrt {:.2}%  (paper reports 1.20% on real MNIST-8M at B=10k)",
        err_native * 100.0,
        err_accel * 100.0
    );

    // ---------- persistence round-trip ----------
    let path = std::env::temp_dir().join("e2e_model.lpd");
    model_io::save(&model_native, &path)?;
    let loaded = model_io::load(&path)?;
    let err_loaded = loaded.error_rate(&test_set.x, &test_set.labels)?;
    anyhow::ensure!(
        (err_loaded - err_native).abs() < 1e-12,
        "persistence changed predictions"
    );
    println!("model save/load round-trip ✓ ({})", path.display());

    println!("\nE2E: all layers composed (Pallas → HLO → PJRT → L3 solver) — PASS");
    Ok(())
}
