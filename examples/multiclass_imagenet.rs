//! The paper's headline multiclass workload: ImageNet-style one-versus-one
//! training. At full scale the paper trains C(1000,2) ≈ half a million
//! binary classifiers in 24 minutes (< 3 ms per binary problem); this
//! example runs the same pipeline on a scaled analogue and reports the
//! same per-problem metric.
//!
//!     cargo run --release --example multiclass_imagenet
//!     LPDSVM_EXAMPLE_SCALE=0.01 cargo run --release --example multiclass_imagenet

use lpdsvm::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::var("LPDSVM_EXAMPLE_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.002);
    let spec = PaperDataset::ImageNet.spec(scale, 42);
    let data = spec.synth.generate();
    let n_pairs = data.n_classes * (data.n_classes - 1) / 2;
    println!(
        "ImageNet analogue at scale {scale}: n={} p={} classes={} → {} OVO pairs (paper: 1000 classes, 499,500 pairs)",
        data.len(),
        data.dim(),
        data.n_classes,
        n_pairs,
    );

    let mut rng = Rng::new(9);
    let (train_set, test_set) = data.split(0.2, &mut rng);

    let cfg = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: spec.budget,
            ..Default::default()
        },
        solver: SolverOptions {
            c: spec.c,
            ..Default::default()
        },
        compact_pairs: true, // each pair touches 2n/c rows — compaction wins
        ..Default::default()
    };

    let mut clock = StageClock::new();
    let model = lpdsvm::coordinator::train::train_with_backend(
        &train_set,
        &cfg,
        &NativeBackend::default(),
        &mut clock,
    )?;

    let linear_s = clock.secs("linear_train");
    println!("stage timings:");
    for (stage, secs) in clock.entries() {
        println!("  {stage:<14} {secs:.3}s");
    }
    println!(
        "{} binary classifiers in {:.3}s → {:.3} ms per binary problem (paper: <3 ms)",
        model.heads.len(),
        linear_s,
        1e3 * linear_s / model.heads.len() as f64
    );
    let converged = model.heads.iter().filter(|h| h.converged).count();
    println!(
        "converged heads: {converged}/{} — mean SVs per pair: {:.1}",
        model.heads.len(),
        model.heads.iter().map(|h| h.sv_count).sum::<usize>() as f64 / model.heads.len() as f64
    );

    let err = model.error_rate(&test_set.x, &test_set.labels)?;
    println!(
        "test error {:.2}% over {} classes (paper reports 37.5% on real ImageNet features)",
        err * 100.0,
        data.n_classes
    );
    Ok(())
}
