//! Quickstart: train an LPD-SVM on a small binary problem, evaluate it,
//! save it, load it back.
//!
//!     cargo run --release --example quickstart

use lpdsvm::model::io as model_io;
use lpdsvm::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data: an Adult(a9a)-analogue at 2% of the paper's size. Real
    //    LIBSVM files load with `lpdsvm::data::libsvm::read` instead.
    let spec = PaperDataset::Adult.spec(0.02, 42);
    let data = spec.synth.generate();
    let mut rng = Rng::new(7);
    let (train_set, test_set) = data.split(0.2, &mut rng);
    println!(
        "dataset: {} train / {} test, {} features, density {:.3}",
        train_set.len(),
        test_set.len(),
        data.dim(),
        data.x.density()
    );

    // 2. Configure: Gaussian kernel with the table-1 hyperparameters; the
    //    stage-1 budget B controls the accuracy/speed trade-off.
    let cfg = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: spec.budget,
            ..Default::default()
        },
        solver: SolverOptions {
            c: spec.c,
            ..Default::default()
        },
        ..Default::default()
    };

    // 3. Train (stage 1: landmarks → eigh → G; stage 2: dual CD with
    //    shrinking) and evaluate.
    let mut clock = StageClock::new();
    let model = lpdsvm::coordinator::train::train_with_backend(
        &train_set,
        &cfg,
        &NativeBackend::default(),
        &mut clock,
    )?;
    println!(
        "trained: rank={} (from budget {}), SVs={}, G holds {:.1} MiB",
        model.factor.rank,
        spec.budget,
        model.heads[0].sv_count,
        model.factor.g_bytes() as f64 / (1024.0 * 1024.0)
    );
    for (stage, secs) in clock.entries() {
        println!("  {stage:<14} {secs:.3}s");
    }
    let err = model.error_rate(&test_set.x, &test_set.labels)?;
    println!("test error: {:.2}%", err * 100.0);

    // 4. Persist and reload.
    let path = std::env::temp_dir().join("quickstart.lpd");
    model_io::save(&model, &path)?;
    let loaded = model_io::load(&path)?;
    let err2 = loaded.error_rate(&test_set.x, &test_set.labels)?;
    assert_eq!(err, err2, "reloaded model must predict identically");
    println!("saved + reloaded: {} (error matches)", path.display());
    Ok(())
}
