//! Property tests over the data substrate: LIBSVM round-trips, scaler
//! invariants, CSR/dense agreement, fold exhaustiveness.

use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::folds::Folds;
use lpdsvm::data::scale::MinMaxScaler;
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::libsvm;
use lpdsvm::testing::prop::{forall, usize_in, Gen};
use lpdsvm::util::rng::Rng;

/// A random sparse labeled dataset.
#[derive(Clone, Debug)]
struct RandomData {
    n: usize,
    p: usize,
    classes: usize,
    density: f64,
    seed: u64,
}

fn data_gen() -> Gen<RandomData> {
    Gen::new(
        |rng: &mut Rng| RandomData {
            n: 2 + rng.usize(60),
            p: 1 + rng.usize(20),
            classes: 2 + rng.usize(4),
            density: 0.1 + rng.f64() * 0.8,
            seed: rng.next_u64(),
        },
        |d| {
            let mut out = Vec::new();
            if d.n > 2 {
                out.push(RandomData { n: 2 + (d.n - 2) / 2, ..d.clone() });
            }
            if d.p > 1 {
                out.push(RandomData { p: 1, ..d.clone() });
            }
            out
        },
    )
}

fn materialise(d: &RandomData) -> Dataset {
    let mut rng = Rng::new(d.seed);
    let mut rows = Vec::with_capacity(d.n);
    for _ in 0..d.n {
        let mut row = Vec::new();
        for c in 0..d.p as u32 {
            if rng.bool(d.density) {
                // Quantised values so text round-trips are exact.
                let v = (rng.normal() * 8.0).round() as f32 / 8.0;
                if v != 0.0 {
                    row.push((c, v));
                }
            }
        }
        rows.push(row);
    }
    // Guarantee every class appears at least once when n allows.
    let labels: Vec<u32> = (0..d.n).map(|i| (i % d.classes) as u32).collect();
    let classes = d.classes.min(d.n);
    let labels = labels.into_iter().map(|l| l.min(classes as u32 - 1)).collect();
    Dataset::new("prop", SparseMatrix::from_rows(d.p, &rows), labels, classes)
}

#[test]
fn prop_libsvm_roundtrip_exact() {
    forall("libsvm-roundtrip", 30, &data_gen(), |d| {
        let ds = materialise(d);
        if ds.n_classes < 2 {
            return Ok(());
        }
        let dir = std::env::temp_dir().join("lpdsvm_prop_data");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("rt_{}.svm", d.seed));
        libsvm::write(&ds, &path).map_err(|e| e.to_string())?;
        let back = libsvm::read(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if back.len() != ds.len() {
            return Err(format!("n {} vs {}", back.len(), ds.len()));
        }
        if back.labels != ds.labels {
            return Err("labels changed".into());
        }
        // Feature matrix identical up to the (possibly smaller) read width
        // — trailing all-zero columns are not representable in the format.
        let a = ds.x.to_dense();
        let b = back.x.to_dense();
        for i in 0..ds.len() {
            for j in 0..ds.dim() {
                let bv = if j < b.cols { b.at(i, j) } else { 0.0 };
                if (a.at(i, j) - bv).abs() > 1e-6 {
                    return Err(format!("({i},{j}): {} vs {bv}", a.at(i, j)));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_minmax_scaler_bounds_and_idempotence() {
    forall("minmax-bounds", 30, &data_gen(), |d| {
        let ds = materialise(d);
        let scaler = MinMaxScaler::fit(&ds.x);
        let t = scaler.transform(&ds.x);
        for i in 0..t.rows {
            let (_, vals) = t.row(i);
            for &v in vals {
                if !(-1e-6..=1.0 + 1e-6).contains(&v) {
                    return Err(format!("scaled value {v} outside [0,1]"));
                }
            }
        }
        // Idempotence holds only for non-negative data: with negative
        // values, implicit zeros map to a positive target that a sparse
        // transform cannot materialise (svm-scale shares this caveat, see
        // data::scale docs), so a refit sees a different attained range.
        if ds.x.values.iter().all(|&v| v >= 0.0) {
            let scaler2 = MinMaxScaler::fit(&t);
            let t2 = scaler2.transform(&t);
            if (t2.to_dense().max_abs_diff(&t.to_dense())) > 1e-5 {
                return Err("second scaling moved values".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_dense_row_dots_agree() {
    forall("sparse-dense-dot", 30, &data_gen(), |d| {
        let ds = materialise(d);
        let dense = ds.x.to_dense();
        for i in (0..ds.len()).step_by(3) {
            for j in (0..ds.len()).step_by(5) {
                let sp = ds.x.row_dot(i, &ds.x, j);
                let dn: f32 = dense
                    .row(i)
                    .iter()
                    .zip(dense.row(j))
                    .map(|(a, b)| a * b)
                    .sum();
                if (sp - dn).abs() > 1e-4 * (1.0 + dn.abs()) {
                    return Err(format!("dot({i},{j}) {sp} vs {dn}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_folds_partition_and_stratify() {
    forall("folds-partition", 30, &usize_in(10, 200), |&n| {
        let labels: Vec<u32> = (0..n).map(|i| (i % 3) as u32).collect();
        let k = 2 + n % 4;
        let folds = Folds::stratified(&labels, k, &mut Rng::new(n as u64));
        let mut seen = vec![0u32; n];
        for f in 0..k {
            let (train, val) = folds.split(f);
            if train.len() + val.len() != n {
                return Err("split does not partition".into());
            }
            for &i in &val {
                seen[i] += 1;
            }
        }
        if seen.iter().any(|&s| s != 1) {
            return Err("each point must be validated exactly once".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ovo_subproblems_cover_all_points_once_per_pair() {
    forall("ovo-cover", 20, &data_gen(), |d| {
        let ds = materialise(d);
        let mut seen = vec![0usize; ds.len()];
        for (a, b) in ds.class_pairs() {
            let (_, idx) = ds.ovo_subproblem(a, b);
            for &i in &idx {
                if ds.labels[i] != a && ds.labels[i] != b {
                    return Err(format!("row {i} wrong class in pair ({a},{b})"));
                }
                seen[i] += 1;
            }
        }
        // Each point appears in exactly (classes − 1) pairs.
        let want = ds.n_classes - 1;
        for (i, &s) in seen.iter().enumerate() {
            if s != want {
                return Err(format!("row {i} in {s} pairs, want {want}"));
            }
        }
        Ok(())
    });
}
