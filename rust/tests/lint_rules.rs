//! Fixture corpus for the invariant lint engine (`src/analysis/`).
//!
//! One firing and one clean snippet per rule, plus the pragma
//! suppression paths and a self-check that the crate's own tree is
//! lint-clean. Fixture paths are virtual — the path string alone
//! decides which path-scoped rules apply (see `lint_source`).

use lpdsvm::analysis::{lint_files, lint_source, run_lint};
use std::path::Path;

fn rules_fired(findings: &[lpdsvm::analysis::Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------
// Rule 1: unsafe-safety-comment
// ---------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_fires() {
    let src = r#"
pub fn store(p: *mut u8) {
    unsafe { *p = 1 };
}
"#;
    let f = lint_source("util/x.rs", src);
    assert_eq!(rules_fired(&f), ["unsafe-safety-comment"]);
}

#[test]
fn unsafe_with_safety_comment_is_clean() {
    let src = r#"
pub fn store(p: *mut u8) {
    // SAFETY: caller guarantees `p` is valid and exclusively owned.
    unsafe { *p = 1 };
}
"#;
    assert!(lint_source("util/x.rs", src).is_empty());
}

#[test]
fn safety_comment_reaches_through_attributes_and_blanks() {
    let src = r#"
// SAFETY: the pointer is pinned for the program's lifetime.

#[allow(dead_code)]
unsafe fn poke(p: *mut u8) {
    *p = 1;
}
"#;
    assert!(lint_source("util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 2: atomic-ordering-justified
// ---------------------------------------------------------------------

#[test]
fn relaxed_without_justification_fires() {
    let src = r#"
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
"#;
    let f = lint_source("obs/x.rs", src);
    assert_eq!(rules_fired(&f), ["atomic-ordering-justified"]);
}

#[test]
fn relaxed_with_adjacent_justification_is_clean() {
    let src = r#"
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    // Relaxed: monotone telemetry counter, no data published through it.
    c.fetch_add(1, Ordering::Relaxed);
}
"#;
    assert!(lint_source("obs/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 3: determinism-domain
// ---------------------------------------------------------------------

#[test]
fn hashmap_in_solver_fires() {
    let src = r#"
use std::collections::HashMap;
pub fn weights() -> HashMap<usize, f64> {
    HashMap::new()
}
"#;
    let f = lint_source("solver/x.rs", src);
    assert_eq!(f.iter().filter(|f| f.rule == "determinism-domain").count(), 3);
}

#[test]
fn wall_clock_in_solver_fires() {
    let src = r#"
use std::time::Instant;
pub fn stamp() -> std::time::Instant {
    Instant::now()
}
"#;
    let f = lint_source("solver/x.rs", src);
    assert_eq!(rules_fired(&f), ["determinism-domain"]);
}

#[test]
fn same_code_outside_the_domain_is_clean() {
    let src = r#"
use std::collections::HashMap;
pub fn weights() -> HashMap<usize, f64> {
    HashMap::new()
}
"#;
    assert!(lint_source("serve/x.rs", src).is_empty());
}

#[test]
fn btreemap_in_solver_is_clean() {
    let src = r#"
use std::collections::BTreeMap;
pub fn weights() -> BTreeMap<usize, f64> {
    BTreeMap::new()
}
"#;
    assert!(lint_source("solver/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 4: lock-order
// ---------------------------------------------------------------------

#[test]
fn conflicting_lock_order_fires() {
    // `first` takes alpha before beta, `second` the reverse — a static
    // deadlock cycle. The helper's first argument names the lock.
    let src = r#"
impl Engine {
    fn first(&self) {
        let _a = lock_or_abort(&self.alpha, "alpha state");
        let _b = lock_or_abort(&self.beta, "beta state");
    }
    fn second(&self) {
        let _b = lock_or_abort(&self.beta, "beta state");
        let _a = lock_or_abort(&self.alpha, "alpha state");
    }
}
"#;
    let f = lint_source("serve/engine.rs", src);
    assert!(
        f.iter().any(|f| f.rule == "lock-order" && f.msg.contains("cycle")),
        "expected a lock-order cycle finding, got: {f:?}"
    );
}

#[test]
fn consistent_lock_order_is_clean() {
    let src = r#"
impl Engine {
    fn first(&self) {
        let _a = lock_or_abort(&self.alpha, "alpha state");
        let _b = lock_or_abort(&self.beta, "beta state");
    }
    fn second(&self) {
        let _a = lock_or_abort(&self.alpha, "alpha state");
        let _b = lock_or_abort(&self.beta, "beta state");
    }
}
"#;
    assert!(lint_source("serve/engine.rs", src).is_empty());
}

#[test]
fn reacquiring_a_held_lock_fires() {
    let src = r#"
impl Pool {
    fn relock(&self) {
        let _a = self.queue.lock();
        let _b = self.queue.lock();
    }
}
"#;
    let f = lint_source("util/threads.rs", src);
    assert!(
        f.iter().any(|f| f.rule == "lock-order" && f.msg.contains("re-acquired")),
        "expected a re-acquisition finding, got: {f:?}"
    );
}

#[test]
fn dropping_the_guard_releases_the_edge() {
    // With the first guard dropped before the second acquisition the
    // two locks are never held together — no edge, no cycle.
    let src = r#"
impl Engine {
    fn first(&self) {
        let a = lock_or_abort(&self.alpha, "alpha state");
        drop(a);
        let _b = lock_or_abort(&self.beta, "beta state");
    }
    fn second(&self) {
        let b = lock_or_abort(&self.beta, "beta state");
        drop(b);
        let _a = lock_or_abort(&self.alpha, "alpha state");
    }
}
"#;
    assert!(lint_source("serve/engine.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 5: panic-policy
// ---------------------------------------------------------------------

#[test]
fn unwrap_on_the_serve_path_fires() {
    let src = r#"
pub fn head(v: &[u8]) -> u8 {
    let first = v.first().copied();
    first.unwrap()
}
"#;
    let f = lint_source("serve/http.rs", src);
    assert_eq!(rules_fired(&f), ["panic-policy"]);
}

#[test]
fn indexing_on_the_serve_path_fires() {
    let src = r#"
pub fn head(v: &[u8]) -> u8 {
    v[0]
}
"#;
    let f = lint_source("serve/engine.rs", src);
    assert_eq!(rules_fired(&f), ["panic-policy"]);
}

#[test]
fn fallible_serve_code_is_clean() {
    let src = r#"
pub fn head(v: &[u8]) -> Result<u8, String> {
    v.first().copied().ok_or_else(|| "empty body".to_string())
}
"#;
    assert!(lint_source("serve/http.rs", src).is_empty());
}

#[test]
fn panicking_code_off_the_serve_path_is_exempt() {
    let src = r#"
pub fn head(v: &[u8]) -> u8 {
    v[0]
}
"#;
    assert!(lint_source("solver/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// Rule 6: fault-point-registry
// ---------------------------------------------------------------------

fn fault_registry_fixture() -> (String, String) {
    let src = r#"
pub const FAULT_POINTS: &[&str] = &[
    "ckpt.after_tmp_write",
    "serve.worker",
];
"#;
    ("util/fault.rs".to_string(), src.to_string())
}

#[test]
fn unregistered_fault_point_fires() {
    let user = r#"
pub fn run() -> Result<(), String> {
    fault::point("serve.wrker")
}
"#;
    let f = lint_files(&[
        fault_registry_fixture(),
        ("serve/x.rs".to_string(), user.to_string()),
    ]);
    assert_eq!(rules_fired(&f), ["fault-point-registry"]);
    assert!(f[0].msg.contains("serve.wrker"), "msg: {}", f[0].msg);
}

#[test]
fn registered_fault_point_is_clean() {
    let user = r#"
pub fn run() -> Result<(), String> {
    fault::point("serve.worker")
}
"#;
    let f = lint_files(&[
        fault_registry_fixture(),
        ("serve/x.rs".to_string(), user.to_string()),
    ]);
    assert!(f.is_empty(), "unexpected findings: {f:?}");
}

// ---------------------------------------------------------------------
// Pragma suppression
// ---------------------------------------------------------------------

#[test]
fn line_pragma_suppresses_one_site() {
    let src = r#"
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    // lint: allow(atomic-ordering-justified)
    c.fetch_add(1, Ordering::Relaxed);
    c.fetch_add(2, Ordering::Relaxed);
}
"#;
    // The pragma covers only the adjacent line — the second site still
    // fires, so pragmas cannot blanket-disable a rule by accident.
    let f = lint_source("obs/x.rs", src);
    assert_eq!(rules_fired(&f), ["atomic-ordering-justified"]);
    assert_eq!(f[0].line, 6);
}

#[test]
fn file_pragma_suppresses_the_whole_file() {
    let src = r#"
// lint: allow-file(atomic-ordering-justified) — fixture: the whole
// module is telemetry counters.
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
    c.fetch_add(2, Ordering::Relaxed);
}
"#;
    assert!(lint_source("obs/x.rs", src).is_empty());
}

#[test]
fn pragma_for_a_different_rule_does_not_suppress() {
    let src = r#"
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    // lint: allow(panic-policy)
    c.fetch_add(1, Ordering::Relaxed);
}
"#;
    let f = lint_source("obs/x.rs", src);
    assert_eq!(rules_fired(&f), ["atomic-ordering-justified"]);
}

// ---------------------------------------------------------------------
// Test scoping: `#[cfg(test)]` and tests/ paths are exempt from the
// runtime-behaviour rules (they may unwrap, index, use HashMap...).
// ---------------------------------------------------------------------

#[test]
fn cfg_test_modules_are_exempt() {
    let src = r#"
pub fn head(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn heads() {
        let v = vec![1u8];
        assert_eq!(v[0], super::head(&v).unwrap());
    }
}
"#;
    assert!(lint_source("serve/http.rs", src).is_empty());
}

#[test]
fn integration_test_paths_are_exempt() {
    let src = r#"
use std::collections::HashMap;
pub fn fixture() -> HashMap<usize, f64> {
    HashMap::new()
}
"#;
    assert!(lint_source("tests/solver/x.rs", src).is_empty());
}

// ---------------------------------------------------------------------
// The crate's own tree must be clean — the same gate CI enforces.
// ---------------------------------------------------------------------

#[test]
fn crate_tree_is_lint_clean() {
    // CARGO_MANIFEST_DIR is `rust/`, so `run_lint` takes its
    // `src` + `tests` fallback.
    let findings = run_lint(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("walking the crate tree");
    assert!(
        findings.is_empty(),
        "the crate tree has lint findings:\n{}",
        findings
            .iter()
            .map(|f| format!("  {f}\n"))
            .collect::<String>()
    );
}
