//! Differential properties of the parallel compute backbone: for every
//! thread count, the row-banded tiled GEMM, the batch kernel blocks, the
//! stage-1 factor and full training must be *bit-identical* to the serial
//! (`threads == 1`) path. Banding only partitions output rows, so each row
//! is computed by exactly one worker in exactly the serial order — these
//! tests pin that contract down across shapes and all four kernels.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::Mat;
use lpdsvm::lowrank::factor::{LowRankFactor, NativeBackend};
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::testing::prop::{forall, Gen};
use lpdsvm::util::rng::Rng;
use lpdsvm::util::timer::StageClock;

const THREADS: [usize; 4] = [1, 2, 3, 8];

fn all_kernels() -> [Kernel; 4] {
    [
        Kernel::gaussian(0.4),
        Kernel::Polynomial {
            gamma: 0.3,
            coef0: 1.0,
            degree: 3,
        },
        Kernel::Tanh {
            gamma: 0.2,
            coef0: -0.1,
        },
        Kernel::Linear,
    ]
}

/// Random GEMM shape, shrinking toward minimal dimensions.
#[derive(Clone, Debug)]
struct GemmShape {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

fn shape_gen() -> Gen<GemmShape> {
    Gen::new(
        |rng: &mut Rng| GemmShape {
            m: 1 + rng.usize(24),
            // Occasionally straddle the KC = 256 tile boundary.
            k: 1 + if rng.bool(0.2) { 250 + rng.usize(20) } else { rng.usize(40) },
            n: 1 + rng.usize(24),
            seed: rng.next_u64(),
        },
        |p| {
            let mut out = Vec::new();
            if p.m > 1 {
                out.push(GemmShape { m: 1 + (p.m - 1) / 2, ..p.clone() });
            }
            if p.k > 1 {
                out.push(GemmShape { k: 1 + (p.k - 1) / 2, ..p.clone() });
            }
            if p.n > 1 {
                out.push(GemmShape { n: 1 + (p.n - 1) / 2, ..p.clone() });
            }
            out
        },
    )
}

fn random_mat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32)
}

#[test]
fn prop_parallel_gemm_bitwise_matches_serial() {
    forall("parallel-gemm", 25, &shape_gen(), |p| {
        let mut rng = Rng::new(p.seed);
        let a = random_mat(p.m, p.k, &mut rng);
        let b = random_mat(p.k, p.n, &mut rng);
        let serial = a.matmul_threads(&b, 1);
        for &t in &THREADS {
            let par = a.matmul_threads(&b, t);
            if serial != par {
                return Err(format!("matmul differs at t={t}"));
            }
        }
        // Cross-check against the naive triple loop (FMA reassociation
        // allows tiny rounding differences, never large ones).
        for i in 0..p.m {
            for j in 0..p.n {
                let mut want = 0.0f64;
                for kk in 0..p.k {
                    want += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                let got = serial.at(i, j) as f64;
                let tol = 5e-4 * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!("({i},{j}): {got} vs naive {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_matmul_nt_bitwise_matches_serial() {
    forall("parallel-matmul-nt", 25, &shape_gen(), |p| {
        let mut rng = Rng::new(p.seed);
        let a = random_mat(p.m, p.k, &mut rng);
        let b = random_mat(p.n, p.k, &mut rng);
        let serial = a.matmul_nt_threads(&b, 1);
        for &t in &THREADS {
            if serial != a.matmul_nt_threads(&b, t) {
                return Err(format!("matmul_nt differs at t={t}"));
            }
        }
        let via_t = a.matmul(&b.transpose());
        let diff = serial.max_abs_diff(&via_t);
        if diff > 1e-3 {
            return Err(format!("matmul_nt vs transpose formulation: diff {diff}"));
        }
        Ok(())
    });
}

/// Random sparse dataset with mixed row densities (exercises both the
/// scatter+SIMD and the gather inner paths of the kernel block).
fn random_sparse(n: usize, p: usize, seed: u64) -> SparseMatrix {
    let mut rng = Rng::new(seed);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for r in 0..n {
        let density = if r % 3 == 0 { 0.05 } else { 0.8 };
        let mut row = Vec::new();
        for c in 0..p as u32 {
            if rng.bool(density) {
                row.push((c, rng.normal() as f32));
            }
        }
        rows.push(row);
    }
    SparseMatrix::from_rows(p, &rows)
}

#[test]
fn prop_kernel_block_bitwise_matches_serial_all_kernels() {
    forall("parallel-kernel-block", 12, &shape_gen(), |p| {
        let n = 2 + p.m;
        let feats = 1 + p.k.min(48);
        let x = random_sparse(n, feats, p.seed);
        let landmarks = random_sparse(1 + p.n.min(12), feats, p.seed ^ 0xABCD).to_dense();
        let lm_sq = landmarks.row_sq_norms();
        let sel: Vec<usize> = (0..n).step_by(2).collect();
        for kernel in all_kernels() {
            let serial = kernel.block_threads(&x, &sel, &landmarks, &lm_sq, 1);
            for &t in &THREADS {
                let par = kernel.block_threads(&x, &sel, &landmarks, &lm_sq, t);
                if serial != par {
                    return Err(format!("{} block differs at t={t}", kernel.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_symmetric_matrix_bitwise_matches_serial() {
    forall("parallel-symmetric-matrix", 12, &shape_gen(), |p| {
        let b = 1 + p.m.min(16);
        let feats = 1 + p.k.min(24);
        let landmarks = random_sparse(b, feats, p.seed).to_dense();
        let sq = landmarks.row_sq_norms();
        for kernel in all_kernels() {
            let serial = kernel.symmetric_matrix_threads(&landmarks, &sq, 1);
            for &t in &THREADS {
                if serial != kernel.symmetric_matrix_threads(&landmarks, &sq, t) {
                    return Err(format!("{} K_BB differs at t={t}", kernel.name()));
                }
            }
        }
        Ok(())
    });
}

fn stage1_dataset(n: usize, p: usize, classes: usize, seed: u64) -> lpdsvm::prelude::Dataset {
    SynthSpec {
        name: "prop-parallel".into(),
        n,
        p,
        n_classes: classes,
        sep: 4.0,
        latent: 4,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate()
}

#[test]
fn stage1_factor_bitwise_identical_across_threads_all_kernels() {
    let data = stage1_dataset(110, 9, 2, 31);
    for kernel in all_kernels() {
        let run = |threads: usize| {
            let cfg = Stage1Config {
                budget: 28,
                chunk: 23, // deliberately not dividing n evenly
                threads,
                seed: 77,
                ..Default::default()
            };
            let mut clock = StageClock::new();
            LowRankFactor::compute(
                &data.x,
                kernel,
                &cfg,
                &NativeBackend::with_threads(threads),
                &mut clock,
            )
            .unwrap()
        };
        let serial = run(1);
        for &t in &THREADS[1..] {
            let par = run(t);
            assert_eq!(serial.g, par.g, "{}: G differs at t={t}", kernel.name());
            assert_eq!(
                serial.whiten,
                par.whiten,
                "{}: whiten differs at t={t}",
                kernel.name()
            );
            assert_eq!(serial.rank, par.rank, "{}: rank differs at t={t}", kernel.name());
            assert_eq!(
                serial.landmark_idx,
                par.landmark_idx,
                "{}: landmarks differ at t={t}",
                kernel.name()
            );
        }
    }
}

#[test]
fn full_training_identical_models_across_threads() {
    // The acceptance contract: parallel and serial training produce
    // *identical* models — same head weights, same predictions.
    let data = stage1_dataset(240, 10, 4, 33);
    let run = |threads: usize| {
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.08),
            stage1: Stage1Config {
                budget: 48,
                seed: 5,
                ..Default::default()
            },
            threads,
            ..Default::default()
        };
        train(&data, &cfg).unwrap()
    };
    let serial = run(1);
    for t in [2usize, 3, 8] {
        let par = run(t);
        assert_eq!(serial.heads.len(), par.heads.len());
        for (hs, hp) in serial.heads.iter().zip(&par.heads) {
            assert_eq!(hs.pair, hp.pair, "t={t}");
            assert_eq!(hs.w, hp.w, "head {:?} weights differ at t={t}", hs.pair);
        }
        let ps = serial.predict(&data.x).unwrap();
        let pp = par.predict(&data.x).unwrap();
        assert_eq!(ps, pp, "predictions differ at t={t}");
    }
}
