//! Differential properties of the parallel compute backbone: for every
//! thread count, the row-banded tiled GEMM, the batch kernel blocks, the
//! stage-1 factor and full training must be *bit-identical* to the serial
//! (`threads == 1`) path. Banding only partitions output rows, so each row
//! is computed by exactly one worker in exactly the serial order — these
//! tests pin that contract down across shapes and all four kernels.
//!
//! Since PR 3 every one of these paths runs on the persistent worker pool
//! (`util::threads::ThreadPool`) instead of scoped per-call spawns, so
//! the same assertions now also pin down the pool's scheduling: dynamic
//! slot claiming decides *who* computes a band, never *what* it computes.
//! The pool-parallel tournament Jacobi (`sym_eig_threads`) has a weaker
//! but sufficient contract, tested below: deterministic for every fixed
//! thread count (bit-identical across counts, in fact) and within the
//! serial solver's accuracy envelope at unchanged tolerances.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::eigen::{sym_eig, sym_eig_threads, sym_eig_tournament};
use lpdsvm::linalg::Mat;
use lpdsvm::lowrank::factor::{LowRankFactor, NativeBackend};
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::testing::prop::{forall, Gen};
use lpdsvm::util::rng::Rng;
use lpdsvm::util::threads::ThreadPool;
use lpdsvm::util::timer::StageClock;

const THREADS: [usize; 4] = [1, 2, 3, 8];

fn all_kernels() -> [Kernel; 4] {
    [
        Kernel::gaussian(0.4),
        Kernel::Polynomial {
            gamma: 0.3,
            coef0: 1.0,
            degree: 3,
        },
        Kernel::Tanh {
            gamma: 0.2,
            coef0: -0.1,
        },
        Kernel::Linear,
    ]
}

/// Random GEMM shape, shrinking toward minimal dimensions.
#[derive(Clone, Debug)]
struct GemmShape {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

fn shape_gen() -> Gen<GemmShape> {
    Gen::new(
        |rng: &mut Rng| GemmShape {
            m: 1 + rng.usize(24),
            // Occasionally straddle the KC = 256 tile boundary.
            k: 1 + if rng.bool(0.2) { 250 + rng.usize(20) } else { rng.usize(40) },
            n: 1 + rng.usize(24),
            seed: rng.next_u64(),
        },
        |p| {
            let mut out = Vec::new();
            if p.m > 1 {
                out.push(GemmShape { m: 1 + (p.m - 1) / 2, ..p.clone() });
            }
            if p.k > 1 {
                out.push(GemmShape { k: 1 + (p.k - 1) / 2, ..p.clone() });
            }
            if p.n > 1 {
                out.push(GemmShape { n: 1 + (p.n - 1) / 2, ..p.clone() });
            }
            out
        },
    )
}

fn random_mat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32)
}

#[test]
fn prop_parallel_gemm_bitwise_matches_serial() {
    forall("parallel-gemm", 25, &shape_gen(), |p| {
        let mut rng = Rng::new(p.seed);
        let a = random_mat(p.m, p.k, &mut rng);
        let b = random_mat(p.k, p.n, &mut rng);
        let serial = a.matmul_threads(&b, 1);
        for &t in &THREADS {
            let par = a.matmul_threads(&b, t);
            if serial != par {
                return Err(format!("matmul differs at t={t}"));
            }
        }
        // Cross-check against the naive triple loop (FMA reassociation
        // allows tiny rounding differences, never large ones).
        for i in 0..p.m {
            for j in 0..p.n {
                let mut want = 0.0f64;
                for kk in 0..p.k {
                    want += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                let got = serial.at(i, j) as f64;
                let tol = 5e-4 * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!("({i},{j}): {got} vs naive {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_matmul_nt_bitwise_matches_serial() {
    forall("parallel-matmul-nt", 25, &shape_gen(), |p| {
        let mut rng = Rng::new(p.seed);
        let a = random_mat(p.m, p.k, &mut rng);
        let b = random_mat(p.n, p.k, &mut rng);
        let serial = a.matmul_nt_threads(&b, 1);
        for &t in &THREADS {
            if serial != a.matmul_nt_threads(&b, t) {
                return Err(format!("matmul_nt differs at t={t}"));
            }
        }
        let via_t = a.matmul(&b.transpose());
        let diff = serial.max_abs_diff(&via_t);
        if diff > 1e-3 {
            return Err(format!("matmul_nt vs transpose formulation: diff {diff}"));
        }
        Ok(())
    });
}

/// Random sparse dataset with mixed row densities (exercises both the
/// scatter+SIMD and the gather inner paths of the kernel block).
fn random_sparse(n: usize, p: usize, seed: u64) -> SparseMatrix {
    let mut rng = Rng::new(seed);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for r in 0..n {
        let density = if r % 3 == 0 { 0.05 } else { 0.8 };
        let mut row = Vec::new();
        for c in 0..p as u32 {
            if rng.bool(density) {
                row.push((c, rng.normal() as f32));
            }
        }
        rows.push(row);
    }
    SparseMatrix::from_rows(p, &rows)
}

#[test]
fn prop_kernel_block_bitwise_matches_serial_all_kernels() {
    forall("parallel-kernel-block", 12, &shape_gen(), |p| {
        let n = 2 + p.m;
        let feats = 1 + p.k.min(48);
        let x = random_sparse(n, feats, p.seed);
        let landmarks = random_sparse(1 + p.n.min(12), feats, p.seed ^ 0xABCD).to_dense();
        let lm_sq = landmarks.row_sq_norms();
        let sel: Vec<usize> = (0..n).step_by(2).collect();
        for kernel in all_kernels() {
            let serial = kernel.block_threads(&x, &sel, &landmarks, &lm_sq, 1);
            for &t in &THREADS {
                let par = kernel.block_threads(&x, &sel, &landmarks, &lm_sq, t);
                if serial != par {
                    return Err(format!("{} block differs at t={t}", kernel.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_symmetric_matrix_bitwise_matches_serial() {
    forall("parallel-symmetric-matrix", 12, &shape_gen(), |p| {
        let b = 1 + p.m.min(16);
        let feats = 1 + p.k.min(24);
        let landmarks = random_sparse(b, feats, p.seed).to_dense();
        let sq = landmarks.row_sq_norms();
        for kernel in all_kernels() {
            let serial = kernel.symmetric_matrix_threads(&landmarks, &sq, 1);
            for &t in &THREADS {
                if serial != kernel.symmetric_matrix_threads(&landmarks, &sq, t) {
                    return Err(format!("{} K_BB differs at t={t}", kernel.name()));
                }
            }
        }
        Ok(())
    });
}

fn stage1_dataset(n: usize, p: usize, classes: usize, seed: u64) -> lpdsvm::prelude::Dataset {
    SynthSpec {
        name: "prop-parallel".into(),
        n,
        p,
        n_classes: classes,
        sep: 4.0,
        latent: 4,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate()
}

#[test]
fn stage1_factor_bitwise_identical_across_threads_all_kernels() {
    let data = stage1_dataset(110, 9, 2, 31);
    for kernel in all_kernels() {
        let run = |threads: usize| {
            let cfg = Stage1Config {
                budget: 28,
                chunk: 23, // deliberately not dividing n evenly
                threads,
                seed: 77,
                ..Default::default()
            };
            let mut clock = StageClock::new();
            LowRankFactor::compute(
                &data.x,
                kernel,
                &cfg,
                &NativeBackend::with_threads(threads),
                &mut clock,
            )
            .unwrap()
        };
        let serial = run(1);
        for &t in &THREADS[1..] {
            let par = run(t);
            assert_eq!(serial.g, par.g, "{}: G differs at t={t}", kernel.name());
            assert_eq!(
                serial.whiten,
                par.whiten,
                "{}: whiten differs at t={t}",
                kernel.name()
            );
            assert_eq!(serial.rank, par.rank, "{}: rank differs at t={t}", kernel.name());
            assert_eq!(
                serial.landmark_idx,
                par.landmark_idx,
                "{}: landmarks differ at t={t}",
                kernel.name()
            );
        }
    }
}

#[test]
fn prop_private_pool_gemm_bitwise_matches_global_pool() {
    // The pool API itself (not just the global-pool free functions):
    // explicit `ThreadPool::chunks` banding must reproduce the library
    // GEMM bit for bit, for private pools of any size.
    let pool = ThreadPool::new(3);
    forall("private-pool-gemm", 10, &shape_gen(), |p| {
        let mut rng = Rng::new(p.seed);
        let a = random_mat(p.m, p.k, &mut rng);
        let b = random_mat(p.k, p.n, &mut rng);
        let serial = a.matmul_threads(&b, 1);
        for &t in &THREADS {
            let mut out = Mat::zeros(p.m, p.n);
            pool.chunks(&mut out.data, p.n, t, |rows, band| {
                for (bi, i) in rows.enumerate() {
                    for j in 0..p.n {
                        let mut s = 0.0f32;
                        for kk in 0..p.k {
                            s += a.at(i, kk) * b.at(kk, j);
                        }
                        band[bi * p.n + j] = s;
                    }
                }
            });
            // Same banding, same per-row arithmetic → same floats as a
            // naive row loop; compare against the naive serial loop.
            let mut naive = Mat::zeros(p.m, p.n);
            for i in 0..p.m {
                for j in 0..p.n {
                    let mut s = 0.0f32;
                    for kk in 0..p.k {
                        s += a.at(i, kk) * b.at(kk, j);
                    }
                    naive.set(i, j, s);
                }
            }
            if out != naive {
                return Err(format!("pool naive GEMM differs at t={t}"));
            }
            // And the tiled library kernel stays within FMA rounding.
            let diff = out.max_abs_diff(&serial);
            if diff > 1e-3 {
                return Err(format!("pool vs tiled GEMM diff {diff} at t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn pool_map_results_independent_of_scheduling() {
    // parallel_map order contract on the shared pool: results collected
    // in index order whatever the interleaving; repeated runs identical.
    let jobs: Vec<u64> = (0..300).map(|i| (i as u64) * 17 % 101).collect();
    let reference: Vec<u64> = jobs.iter().map(|&x| x * x + 1).collect();
    for &t in &THREADS {
        for _rep in 0..3 {
            let got = lpdsvm::util::threads::parallel_map(jobs.len(), t, |i| {
                jobs[i] * jobs[i] + 1
            });
            assert_eq!(got, reference, "t={t}");
        }
    }
}

#[test]
fn sym_eig_threads_deterministic_and_accurate_per_thread_count() {
    // Acceptance contract for the parallel Jacobi: per-thread-count
    // determinism plus the serial suite's tolerances, on both an even and
    // an odd dimension (the odd case exercises the phantom seat). The
    // tournament variant is exercised directly — `sym_eig_threads` would
    // route these small matrices to the serial path (its size-only
    // cutover is pinned down by `threads_entry_point_cuts_over_on_size_
    // only` in linalg::eigen and by the 160-dim case below).
    for (n, seed) in [(20usize, 51u64), (17, 52)] {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal() as f32;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        let serial = sym_eig(&a, 50, 1e-13);
        let reference = sym_eig_tournament(&a, 50, 1e-13, 1);
        for &t in &THREADS {
            let once = sym_eig_tournament(&a, 50, 1e-13, t);
            let twice = sym_eig_tournament(&a, 50, 1e-13, t);
            assert_eq!(once.values, twice.values, "n={n} t={t} nondeterministic");
            assert_eq!(once.vectors, twice.vectors, "n={n} t={t} nondeterministic");
            // The tournament ordering is scheduling-independent, so every
            // thread count reproduces t=1 exactly.
            assert_eq!(once.values, reference.values, "n={n} t={t} vs t=1");
            assert_eq!(once.vectors, reference.vectors, "n={n} t={t} vs t=1");

            // Accuracy at the serial suite's unchanged tolerances.
            for (lp, ls) in once.values.iter().zip(&serial.values) {
                assert!((lp - ls).abs() < 1e-6, "n={n} t={t}: {lp} vs {ls}");
            }
            let vt_v = once.vectors.transpose().matmul(&once.vectors);
            assert!(
                vt_v.max_abs_diff(&Mat::eye(n)) < 1e-5,
                "n={n} t={t}: eigenvectors not orthonormal"
            );
            let recon = Mat::from_fn(n, n, |i, j| {
                (0..n)
                    .map(|k| {
                        once.vectors.at(i, k) as f64
                            * once.values[k]
                            * once.vectors.at(j, k) as f64
                    })
                    .sum::<f64>() as f32
            });
            assert!(
                a.max_abs_diff(&recon) < 1e-4,
                "n={n} t={t}: reconstruction off by {}",
                a.max_abs_diff(&recon)
            );
        }
    }

    // Above the size cutover the public entry point itself runs the
    // pooled tournament; it must be deterministic per thread count too.
    let n = 160;
    let mut rng = Rng::new(53);
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() as f32;
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    let reference = sym_eig_threads(&a, 40, 1e-12, 1);
    for &t in &THREADS {
        let e = sym_eig_threads(&a, 40, 1e-12, t);
        assert_eq!(e.values, reference.values, "entry point differs at t={t}");
        assert_eq!(e.vectors, reference.vectors, "entry point differs at t={t}");
    }
}

#[test]
fn full_training_identical_models_across_threads() {
    // The acceptance contract: parallel and serial training produce
    // *identical* models — same head weights, same predictions.
    let data = stage1_dataset(240, 10, 4, 33);
    let run = |threads: usize| {
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.08),
            stage1: Stage1Config {
                budget: 48,
                seed: 5,
                ..Default::default()
            },
            threads,
            ..Default::default()
        };
        train(&data, &cfg).unwrap()
    };
    let serial = run(1);
    for t in [2usize, 3, 8] {
        let par = run(t);
        assert_eq!(serial.heads.len(), par.heads.len());
        for (hs, hp) in serial.heads.iter().zip(&par.heads) {
            assert_eq!(hs.pair, hp.pair, "t={t}");
            assert_eq!(hs.w, hp.w, "head {:?} weights differ at t={t}", hs.pair);
        }
        let ps = serial.predict(&data.x).unwrap();
        let pp = par.predict(&data.x).unwrap();
        assert_eq!(ps, pp, "predictions differ at t={t}");
    }
}
