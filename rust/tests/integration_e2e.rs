//! Cross-module integration tests: full pipelines at tiny scale.

use lpdsvm::baselines::exact_smo::{ExactSmo, ExactSmoOptions};
use lpdsvm::coordinator::cv::{cross_validate, CvConfig};
use lpdsvm::coordinator::grid::{grid_search, GridConfig};
use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::data::{dataset::Dataset, libsvm};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::model::io as model_io;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::rng::Rng;

fn quick_cfg(gamma: f64, c: f64, budget: usize) -> TrainConfig {
    TrainConfig {
        kernel: Kernel::gaussian(gamma),
        stage1: Stage1Config {
            budget,
            ..Default::default()
        },
        solver: SolverOptions {
            c,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn every_paper_dataset_trains_and_generalises() {
    // Error ceilings per analogue at tiny scale — generous, but they catch
    // any wholesale regression in the pipeline (e.g. broken whitening).
    let ceilings = [
        (PaperDataset::Adult, 0.30),
        (PaperDataset::Epsilon, 0.25),
        (PaperDataset::Susy, 0.40),
        (PaperDataset::Mnist8m, 0.25),
        // ~44 classes at this scale with ~46 train points each — random
        // guessing would be ≈ 98%, the paper's real-feature error is 37.5%.
        (PaperDataset::ImageNet, 0.85),
    ];
    for (ds, ceiling) in ceilings {
        // 800-point floor: below that, a 25% hold-out is too few points
        // for the ceiling to be more than coin-flip noise.
        let spec = ds.spec(ds.scale_with_floor(0.002, 800), 42);
        let data = spec.synth.generate();
        let mut rng = Rng::new(1);
        let (train_set, test_set) = data.split(0.25, &mut rng);
        let cfg = quick_cfg(spec.gamma, spec.c, spec.budget.min(256));
        let model = train(&train_set, &cfg).unwrap();
        let err = model.error_rate(&test_set.x, &test_set.labels).unwrap();
        assert!(
            err < ceiling,
            "{}: test error {:.3} above ceiling {ceiling}",
            ds.name(),
            err
        );
    }
}

#[test]
fn libsvm_roundtrip_preserves_training_behaviour() {
    let spec = PaperDataset::Adult.spec(0.004, 7);
    let data = spec.synth.generate();
    let dir = std::env::temp_dir().join("lpdsvm_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("adult_tiny.svm");
    libsvm::write(&data, &path).unwrap();
    let reloaded = libsvm::read(&path).unwrap();
    assert_eq!(reloaded.len(), data.len());

    let cfg = quick_cfg(spec.gamma, spec.c, 64);
    let m1 = train(&data, &cfg).unwrap();
    let m2 = train(&reloaded, &cfg).unwrap();
    let p1 = m1.predict(&data.x).unwrap();
    let p2 = m2.predict(&reloaded.x).unwrap();
    assert_eq!(p1, p2, "training on round-tripped data must match");
    std::fs::remove_file(&path).ok();
}

#[test]
fn model_file_predicts_identically_after_reload() {
    let spec = PaperDataset::Mnist8m.spec(0.0002, 3);
    let data = spec.synth.generate();
    let cfg = quick_cfg(spec.gamma, spec.c, 48);
    let model = train(&data, &cfg).unwrap();
    let dir = std::env::temp_dir().join("lpdsvm_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mc.lpd");
    model_io::save(&model, &path).unwrap();
    let loaded = model_io::load(&path).unwrap();
    assert_eq!(
        model.predict(&data.x).unwrap(),
        loaded.predict(&data.x).unwrap()
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn lpd_tracks_exact_solver_accuracy() {
    // Table-2 shape at miniature scale: LPD within a few points of exact.
    let spec = PaperDataset::Adult.spec(0.008, 11);
    let data = spec.synth.generate();
    let mut rng = Rng::new(5);
    let (train_set, test_set) = data.split(0.3, &mut rng);

    let exact = ExactSmo::new(
        Kernel::gaussian(spec.gamma),
        ExactSmoOptions {
            c: spec.c,
            ..Default::default()
        },
    )
    .train(&train_set);
    let scores = exact.decision(&test_set.x);
    let y = test_set.signed_labels();
    let exact_err = scores
        .iter()
        .zip(&y)
        .filter(|(s, y)| (**s > 0.0) != (**y > 0.0))
        .count() as f64
        / y.len() as f64;

    let model = train(&train_set, &quick_cfg(spec.gamma, spec.c, spec.budget.min(256))).unwrap();
    let lpd_err = model.error_rate(&test_set.x, &test_set.labels).unwrap();
    assert!(
        lpd_err <= exact_err + 0.06,
        "LPD err {lpd_err:.3} too far above exact {exact_err:.3}"
    );
}

#[test]
fn cv_and_grid_compose() {
    let spec = PaperDataset::Susy.spec(0.00006, 13);
    let data = spec.synth.generate();
    let cfg = quick_cfg(spec.gamma, spec.c, 32);
    let cv = cross_validate(&data, &cfg, &CvConfig { folds: 3, seed: 2 }).unwrap();
    assert_eq!(cv.fold_errors.len(), 3);

    let grid = GridConfig {
        c_values: vec![1.0, 8.0],
        gamma_values: vec![spec.gamma],
        cv_folds: 3,
        seed: 2,
        warm_start: true,
    };
    let gr = grid_search(&data, &cfg, &grid).unwrap();
    assert_eq!(gr.points.len(), 2);
    assert!(gr.n_binary_problems == 6);
    // The fixed-γ grid at C=1/8 must bracket the plain CV result sanely.
    assert!(gr.best_error <= cv.mean_error + 0.1);
}

#[test]
fn unbalanced_classes_train() {
    // Failure-injection style: 95/5 class imbalance must not panic and
    // must beat always-majority slightly with tuned C.
    let spec = PaperDataset::Adult.spec(0.004, 17);
    let mut data = spec.synth.generate();
    // Drop most of class 1.
    let keep: Vec<usize> = (0..data.len())
        .filter(|&i| data.labels[i] == 0 || i % 8 == 0)
        .collect();
    data = data.subset(&keep);
    let counts = data.class_counts();
    assert!(counts[0] > counts[1] * 3);
    let model = train(&data, &quick_cfg(spec.gamma, spec.c, 64)).unwrap();
    let err = model.error_rate(&data.x, &data.labels).unwrap();
    let majority_err = counts[1] as f64 / data.len() as f64;
    assert!(
        err <= majority_err + 1e-9,
        "err {err:.3} worse than majority baseline {majority_err:.3}"
    );
}

#[test]
fn duplicate_points_and_constant_features_are_handled() {
    // Degenerate data: duplicated rows (singular K_BB — the case that
    // breaks Cholesky and motivates the paper's eigh + truncation) plus an
    // all-constant feature.
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for i in 0..60 {
        let v = if i % 2 == 0 { 1.0 } else { -1.0 };
        // Feature 0 constant, features 1-2 informative, every row repeated.
        rows.push(vec![(0u32, 1.0f32), (1, v), (2, v * 0.5)]);
        labels.push(if i % 2 == 0 { 1u32 } else { 0 });
    }
    let x = lpdsvm::data::sparse::SparseMatrix::from_rows(3, &rows);
    let data = Dataset::new("degenerate", x, labels, 2);
    let model = train(&data, &quick_cfg(0.3, 1.0, 40)).unwrap();
    // Rank must collapse below the budget (duplicates ⇒ singular K_BB).
    assert!(
        model.factor.rank < 40,
        "rank {} should collapse on duplicated data",
        model.factor.rank
    );
    let err = model.error_rate(&data.x, &data.labels).unwrap();
    assert_eq!(err, 0.0, "separable degenerate data must be solved exactly");
}
