//! Integration tests for the serving subsystem: submit → batch → result
//! delivery, agreement with the blocking predict path, hot model swap
//! through the registry, the model-file → registry → engine pipeline, and
//! admission control / load shedding under saturation.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::{FeatureStyle, PaperDataset, SynthSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::Mat;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{LowRankFactor, Stage1Backend, Stage1Config};
use lpdsvm::model::io as model_io;
use lpdsvm::model::multiclass::{BinaryHead, MulticlassModel};
use lpdsvm::model::ModelKind;
use lpdsvm::serve::{
    BackendProvider, ModelRegistry, ServeConfig, ServeEngine, ServeError, ShedPolicy,
};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

fn binary_dataset(seed: u64) -> Dataset {
    PaperDataset::Adult.spec(0.005, seed).synth.generate()
}

fn multiclass_dataset(seed: u64) -> Dataset {
    SynthSpec {
        name: "serve-mc".into(),
        n: 240,
        p: 10,
        n_classes: 4,
        sep: 5.0,
        latent: 4,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate()
}

fn quick_train(data: &Dataset) -> MulticlassModel {
    let cfg = TrainConfig {
        stage1: Stage1Config {
            budget: 24,
            ..Default::default()
        },
        ..Default::default()
    };
    train(data, &cfg).unwrap()
}

fn request_rows(data: &Dataset) -> Vec<Vec<(u32, f32)>> {
    (0..data.len()).map(|i| data.x.row_entries(i)).collect()
}

fn engine_cfg(max_batch: usize, max_wait: Duration, workers: usize) -> ServeConfig {
    ServeConfig {
        max_batch,
        max_wait,
        workers,
        ..ServeConfig::default()
    }
}

#[test]
fn batched_results_match_blocking_predict() {
    let data = multiclass_dataset(11);
    let model = quick_train(&data);
    let expected = model.predict(&data.x).unwrap();

    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(16, Duration::from_millis(2), 2),
    );

    let rows = request_rows(&data);
    let tickets: Vec<_> = rows.iter().map(|r| engine.submit("m", r)).collect();
    let got: Vec<u32> = tickets
        .iter()
        .map(|t| t.wait().expect("prediction delivered").label)
        .collect();
    assert_eq!(got, expected, "engine must agree with MulticlassModel::predict");

    let m = engine.metrics();
    let n = data.len() as u64;
    assert_eq!(m.submitted.load(Ordering::Relaxed), n);
    assert_eq!(m.completed.load(Ordering::Relaxed), n);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    let batches = m.batches.load(Ordering::Relaxed);
    assert!(batches >= n / 16, "at least ⌈n/max_batch⌉ batches");
    assert!(m.latency_us.count() == n);
    engine.shutdown();
}

#[test]
fn size_trigger_forms_full_batches() {
    let data = multiclass_dataset(12);
    let model = quick_train(&data);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    // max_wait far beyond the test horizon (even a preempted CI host won't
    // stall 60s between submits): only the size trigger (8 queued
    // requests) can dispatch, so every prediction must report
    // batch_size == 8.
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(8, Duration::from_secs(60), 1),
    );
    let rows = request_rows(&data);
    let tickets: Vec<_> = rows.iter().take(8).map(|r| engine.submit("m", r)).collect();
    for t in &tickets {
        let pred = t.wait().unwrap();
        assert_eq!(pred.batch_size, 8, "size trigger should fill the batch");
    }
    engine.shutdown();
}

#[test]
fn latency_trigger_dispatches_partial_batch() {
    let data = binary_dataset(13);
    let model = quick_train(&data);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    // Queue 3 requests with a huge max_batch: only max_wait can fire.
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(4096, Duration::from_millis(5), 1),
    );
    let rows = request_rows(&data);
    let tickets: Vec<_> = rows.iter().take(3).map(|r| engine.submit("m", r)).collect();
    for t in &tickets {
        let pred = t.wait().unwrap();
        assert!(pred.batch_size <= 3);
    }
    engine.shutdown();
}

#[test]
fn hot_swap_switches_predictions_without_restart() {
    let data = binary_dataset(14);
    let model_a = quick_train(&data);
    // Model B: identical features, inverted labels — its predictions are
    // (mostly) the complement of A's, making a swap observable.
    let flipped = Dataset::new(
        "flipped",
        data.x.clone(),
        data.labels.iter().map(|&l| 1 - l).collect(),
        2,
    );
    let model_b = quick_train(&flipped);
    let expect_a = model_a.predict(&data.x).unwrap();
    let expect_b = model_b.predict(&data.x).unwrap();
    let disagree = expect_a
        .iter()
        .zip(&expect_b)
        .filter(|(a, b)| a != b)
        .count();
    assert!(
        disagree > data.len() / 2,
        "swap test needs models that disagree (got {disagree}/{})",
        data.len()
    );

    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model_a);
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(32, Duration::from_millis(2), 2),
    );
    let rows = request_rows(&data);

    let round1: Vec<u32> = rows
        .iter()
        .map(|r| engine.submit("m", r).wait().unwrap().label)
        .collect();
    assert_eq!(round1, expect_a);

    // Hot swap while the engine keeps running — no restart, no drain.
    let replaced = registry.insert("m", model_b);
    assert!(replaced.is_some());

    let round2: Vec<u32> = rows
        .iter()
        .map(|r| engine.submit("m", r).wait().unwrap().label)
        .collect();
    assert_eq!(round2, expect_b);
    engine.shutdown();
}

#[test]
fn saved_model_serves_through_registry_load_file() {
    let data = binary_dataset(15);
    let model = quick_train(&data);
    let expected = model.predict(&data.x).unwrap();
    let dir = std::env::temp_dir().join("lpdsvm_serve_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("served.lpd");
    model_io::save(&model, &path).unwrap();

    let registry = Arc::new(ModelRegistry::new());
    registry.load_file("disk", &path).unwrap();
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(64, Duration::from_millis(2), 2),
    );
    let rows = request_rows(&data);
    let got: Vec<u32> = rows
        .iter()
        .map(|r| engine.submit("disk", r).wait().unwrap().label)
        .collect();
    assert_eq!(got, expected);
    engine.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn scoring_panic_rejects_tickets_and_worker_survives() {
    // A structurally broken model — head weight length (3) disagrees with
    // the factor rank (1) — makes scoring panic. The engine must reject
    // that batch's tickets instead of hanging them, and keep serving.
    let broken = MulticlassModel {
        factor: LowRankFactor {
            g: Mat::from_vec(1, 1, vec![1.0]),
            landmarks: Mat::from_vec(1, 1, vec![1.0]),
            landmark_sq: vec![1.0],
            whiten: Mat::from_vec(1, 1, vec![1.0]),
            rank: 1,
            eigenvalues: vec![1.0],
            kernel: Kernel::Linear,
            landmark_idx: vec![0],
        },
        heads: vec![BinaryHead {
            pair: (0, 1),
            w: vec![1.0, 2.0, 3.0], // wrong length on purpose
            objective: 0.0,
            converged: true,
            sv_count: 0,
            steps: 0,
        }],
        kind: ModelKind::Binary,
    };
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", broken);
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(4, Duration::from_millis(2), 1),
    );
    let err = engine.submit("m", &[(0, 1.0)]).wait().unwrap_err();
    assert!(err.to_string().contains("dropped"), "got: {err}");
    assert_eq!(engine.metrics().batch_panics.load(Ordering::Relaxed), 1);
    // The abandoned request still counts as failed (metrics invariant).
    assert_eq!(engine.metrics().failed.load(Ordering::Relaxed), 1);

    // Hot-swap in a sane model: the same (sole) worker must still be alive.
    let data = binary_dataset(17);
    let model = quick_train(&data);
    let expected = model.predict(&data.x).unwrap();
    registry.insert("m", model);
    let rows = request_rows(&data);
    let got: Vec<u32> = rows
        .iter()
        .map(|r| engine.submit("m", r).wait().unwrap().label)
        .collect();
    assert_eq!(got, expected);
    engine.shutdown();
}

#[test]
fn per_request_errors_do_not_poison_the_batch() {
    let data = binary_dataset(16);
    let dim = data.dim() as u32;
    let model = quick_train(&data);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        engine_cfg(8, Duration::from_millis(5), 1),
    );
    let rows = request_rows(&data);
    // One poisoned request (feature index past the model's dimension)
    // sandwiched between good ones.
    let good_before = engine.submit("m", &rows[0]);
    let bad = engine.submit("m", &[(dim + 7, 1.0)]);
    let good_after = engine.submit("m", &rows[1]);
    assert!(good_before.wait().is_ok());
    let err = bad.wait().unwrap_err();
    assert!(err.to_string().contains("out of range"), "got: {err}");
    assert!(good_after.wait().is_ok());
    assert_eq!(engine.metrics().failed.load(Ordering::Relaxed), 1);
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 2);
    engine.shutdown();
}

#[test]
fn bounded_queue_rejects_once_full_and_invariant_holds() {
    // max_wait far beyond the test horizon and max_batch above the cap:
    // nothing can dispatch, so the queue deterministically fills to
    // max_queue and every further submit is shed.
    let registry = Arc::new(ModelRegistry::new());
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(600),
            workers: 1,
            max_queue: 3,
            shed_policy: ShedPolicy::RejectNewest,
            ..ServeConfig::default()
        },
    );
    let queued: Vec<_> = (0..3).map(|_| engine.submit("m", &[(0, 1.0)])).collect();
    assert!(queued.iter().all(|t| t.try_get().is_none()), "still queued");

    // Explicit fast-fail on the Result path…
    let err = engine.try_submit("m", &[(0, 1.0)]).unwrap_err();
    assert_eq!(err, ServeError::QueueFull { max_queue: 3 });
    assert!(err.is_shed());
    // …and an immediately-resolved ticket on the Ticket path.
    let rejected = engine.submit("m", &[(0, 1.0)]);
    let fast_fail = rejected.try_get().expect("queue-full resolves instantly");
    assert_eq!(fast_fail.unwrap_err(), ServeError::QueueFull { max_queue: 3 });

    let m = engine.metrics();
    assert_eq!(m.rejected_full.load(Ordering::Relaxed), 2);
    assert!(m.queue_full_events.load(Ordering::Relaxed) >= 2);
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 3);
    assert!(m.queue_depth_max.load(Ordering::Relaxed) <= 3);

    // Invariant mid-flight: submitted == completed + failed + in-flight.
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed)
            + m.failed.load(Ordering::Relaxed)
            + m.queue_depth.load(Ordering::Relaxed)
    );

    // Shutdown drains the queued three (they fail: model never
    // registered) and the invariant closes with nothing in flight.
    engine.shutdown();
    for t in &queued {
        assert!(t.try_get().expect("drained at shutdown").is_err());
    }
    assert_eq!(m.submitted.load(Ordering::Relaxed), 5);
    assert_eq!(m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed), 5);
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
}

/// A [`Stage1Backend`] that blocks every scoring call on a shared gate —
/// the deterministic way to hold a worker busy while the queue fills.
struct GatedBackend {
    inner: NativeBackend,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Stage1Backend for GatedBackend {
    fn g_chunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        landmarks: &Mat,
        landmark_sq: &[f32],
        whiten: &Mat,
        kernel: &Kernel,
    ) -> anyhow::Result<Mat> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        self.inner.g_chunk(x, rows, landmarks, landmark_sq, whiten, kernel)
    }

    fn name(&self) -> &'static str {
        "gated-native"
    }
}

struct GatedProvider {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl BackendProvider for GatedProvider {
    fn backend(&self) -> anyhow::Result<Box<dyn Stage1Backend + '_>> {
        Ok(Box::new(GatedBackend {
            inner: NativeBackend::default(),
            gate: Arc::clone(&self.gate),
        }))
    }
}

#[test]
fn drop_expired_sheds_overdue_requests_to_admit_new_traffic() {
    let data = binary_dataset(21);
    let model = quick_train(&data);
    let expected = model.predict(&data.x).unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);

    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    // max_wait = 0: every queued request is instantly past its deadline,
    // and the (sole) worker dispatches singleton batches immediately.
    let engine = ServeEngine::start_with_provider(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            workers: 1,
            max_queue: 2,
            shed_policy: ShedPolicy::DropExpired,
            ..ServeConfig::default()
        },
        Arc::new(GatedProvider {
            gate: Arc::clone(&gate),
        }),
    );
    let rows = request_rows(&data);

    // r1 dispatches to the worker, which blocks on the gate. Wait until
    // it actually left the queue so the fill below is deterministic.
    let r1 = engine.submit("m", &rows[0]);
    let t0 = Instant::now();
    while engine.metrics().batches.load(Ordering::Relaxed) < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never picked up r1");
        std::thread::sleep(Duration::from_millis(1));
    }

    // Fill the 2-slot queue behind the blocked worker…
    let r2 = engine.submit("m", &rows[1]);
    let r3 = engine.submit("m", &rows[2]);
    assert!(r2.try_get().is_none() && r3.try_get().is_none(), "queued");
    // Let measurable time pass so both queued requests are unambiguously
    // past the (zero) deadline, then submit one more: the full queue
    // sheds the overdue r2 and r3 and admits r4 instead of rejecting it.
    std::thread::sleep(Duration::from_millis(5));
    let r4 = engine.submit("m", &rows[3]);
    for overdue in [&r2, &r3] {
        let err = overdue.try_get().expect("shed synchronously").unwrap_err();
        assert!(
            matches!(err, ServeError::DeadlineExceeded { .. }),
            "expected a deadline shed, got: {err}"
        );
        assert!(err.is_shed());
    }
    assert!(r4.try_get().is_none(), "r4 was admitted, not rejected");

    let m = engine.metrics();
    assert_eq!(m.shed_expired.load(Ordering::Relaxed), 2);
    assert_eq!(m.rejected_full.load(Ordering::Relaxed), 0);
    assert_eq!(m.queue_full_events.load(Ordering::Relaxed), 1);
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 1);
    // Shedding must make room *before* the newcomer is counted: the
    // high-water mark stays at the cap even on the overflow submit.
    assert!(m.queue_depth_max.load(Ordering::Relaxed) <= 2);

    // Open the gate: the surviving requests score correctly.
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    assert_eq!(r1.wait().unwrap().label, expected[0]);
    assert_eq!(r4.wait().unwrap().label, expected[3]);

    // Invariant after the dust settles: 4 submitted = 2 completed + 2 shed.
    assert_eq!(m.submitted.load(Ordering::Relaxed), 4);
    assert_eq!(m.completed.load(Ordering::Relaxed), 2);
    assert_eq!(m.failed.load(Ordering::Relaxed), 2);
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
    engine.shutdown();
}
