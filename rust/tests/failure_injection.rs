//! Failure-injection tests: corrupted artifacts, hostile inputs, and
//! degenerate numerics must produce clean errors (or correct handling),
//! never panics or NaNs.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::runtime::Runtime;
use lpdsvm::solver::SolverOptions;
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lpdsvm_failinj_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_manifest_is_a_clean_error() {
    let dir = temp_dir("manifest");
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = match Runtime::load(&dir) {
        Ok(_) => panic!("expected error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(!err.is_empty());
}

#[test]
fn manifest_missing_fields_is_a_clean_error() {
    let dir = temp_dir("fields");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "stage1_x"}], "version": 1}"#,
    )
    .unwrap();
    let err = match Runtime::load(&dir) {
        Ok(_) => panic!("expected error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("artifact"), "{err}");
}

#[test]
fn corrupted_hlo_text_fails_at_compile_not_at_load() {
    let dir = temp_dir("hlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "stage1_bad", "file": "bad.hlo.txt", "m": 8, "b": 8, "p": 8}], "version": 1}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage \x01\x02").unwrap();
    let rt = Runtime::load(&dir).expect("manifest itself is fine");
    let meta = rt.artifacts()[0].clone();
    assert!(rt.executable(&meta).is_err(), "garbage HLO must not compile");
}

#[test]
fn empty_manifest_rejected() {
    let dir = temp_dir("empty");
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": [], "version": 1}"#).unwrap();
    assert!(Runtime::load(&dir).is_err());
}

#[test]
fn nan_features_do_not_poison_the_model_silently() {
    // A NaN in the input propagates into kernel values; training must not
    // panic, and the contaminated model must be detectable (finite check).
    let mut rows = vec![vec![(0u32, 1.0f32)], vec![(0, -1.0)]];
    for i in 0..40 {
        let v = if i % 2 == 0 { 1.0 } else { -1.0 };
        rows.push(vec![(0u32, v + 0.01 * i as f32)]);
    }
    rows[0][0].1 = f32::NAN;
    let x = SparseMatrix::from_rows(1, &rows);
    let labels: Vec<u32> = (0..42).map(|i| (i % 2) as u32).collect();
    let data = Dataset::new("nan", x, labels, 2);
    let result = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(0.5),
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    // Either a clean error or a model — but never a panic (reaching this
    // line is the assertion).
    if let Ok(model) = result {
        let _ = model.predict(&data.x);
    }
}

#[test]
fn solver_survives_adversarial_label_flips() {
    // 50% label noise = no learnable signal; solver must converge to a
    // bounded solution (everything at C or 0) without oscillating forever.
    let spec = PaperDataset::Susy.spec(0.00004, 3);
    let mut data = spec.synth.generate();
    for i in 0..data.labels.len() {
        if i % 2 == 0 {
            data.labels[i] = 1 - data.labels[i];
        }
    }
    let model = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 32,
                ..Default::default()
            },
            solver: SolverOptions {
                c: 1.0,
                max_epochs: 200,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(model.heads[0].w.iter().all(|x| x.is_finite()));
}

#[test]
fn budget_larger_than_dataset_is_clamped() {
    let spec = PaperDataset::Adult.spec(0.002, 5);
    let data = spec.synth.generate();
    let model = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: data.len() * 10,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(model.factor.landmarks.rows <= data.len());
}

#[test]
fn truncated_model_file_is_a_clean_error() {
    let spec = PaperDataset::Adult.spec(0.002, 6);
    let data = spec.synth.generate();
    let model = train(
        &data,
        &TrainConfig {
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let dir = temp_dir("model");
    let path = dir.join("full.lpd");
    lpdsvm::model::io::save(&model, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let cut = dir.join("cut.lpd");
    std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
    assert!(lpdsvm::model::io::load(&cut).is_err());
}
