//! Failure-injection tests: corrupted artifacts, hostile inputs, and
//! degenerate numerics must produce clean errors (or correct handling),
//! never panics or NaNs.

use lpdsvm::coordinator::checkpoint::CheckpointCtx;
use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::runtime::Runtime;
use lpdsvm::serve::{ModelRegistry, ServeConfig, ServeEngine, ServeError};
use lpdsvm::solver::{Solution, SolverOptions};
use lpdsvm::util::fault;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lpdsvm_failinj_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_manifest_is_a_clean_error() {
    let dir = temp_dir("manifest");
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = match Runtime::load(&dir) {
        Ok(_) => panic!("expected error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(!err.is_empty());
}

#[test]
fn manifest_missing_fields_is_a_clean_error() {
    let dir = temp_dir("fields");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "stage1_x"}], "version": 1}"#,
    )
    .unwrap();
    let err = match Runtime::load(&dir) {
        Ok(_) => panic!("expected error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("artifact"), "{err}");
}

#[test]
fn corrupted_hlo_text_fails_at_compile_not_at_load() {
    let dir = temp_dir("hlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "stage1_bad", "file": "bad.hlo.txt", "m": 8, "b": 8, "p": 8}], "version": 1}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage \x01\x02").unwrap();
    let rt = Runtime::load(&dir).expect("manifest itself is fine");
    let meta = rt.artifacts()[0].clone();
    assert!(rt.executable(&meta).is_err(), "garbage HLO must not compile");
}

#[test]
fn empty_manifest_rejected() {
    let dir = temp_dir("empty");
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": [], "version": 1}"#).unwrap();
    assert!(Runtime::load(&dir).is_err());
}

#[test]
fn nan_features_do_not_poison_the_model_silently() {
    // A NaN in the input propagates into kernel values; training must not
    // panic, and the contaminated model must be detectable (finite check).
    let mut rows = vec![vec![(0u32, 1.0f32)], vec![(0, -1.0)]];
    for i in 0..40 {
        let v = if i % 2 == 0 { 1.0 } else { -1.0 };
        rows.push(vec![(0u32, v + 0.01 * i as f32)]);
    }
    rows[0][0].1 = f32::NAN;
    let x = SparseMatrix::from_rows(1, &rows);
    let labels: Vec<u32> = (0..42).map(|i| (i % 2) as u32).collect();
    let data = Dataset::new("nan", x, labels, 2);
    let result = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(0.5),
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    // Either a clean error or a model — but never a panic (reaching this
    // line is the assertion).
    if let Ok(model) = result {
        let _ = model.predict(&data.x);
    }
}

#[test]
fn solver_survives_adversarial_label_flips() {
    // 50% label noise = no learnable signal; solver must converge to a
    // bounded solution (everything at C or 0) without oscillating forever.
    let spec = PaperDataset::Susy.spec(0.00004, 3);
    let mut data = spec.synth.generate();
    for i in 0..data.labels.len() {
        if i % 2 == 0 {
            data.labels[i] = 1 - data.labels[i];
        }
    }
    let model = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 32,
                ..Default::default()
            },
            solver: SolverOptions {
                c: 1.0,
                max_epochs: 200,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(model.heads[0].w.iter().all(|x| x.is_finite()));
}

#[test]
fn budget_larger_than_dataset_is_clamped() {
    let spec = PaperDataset::Adult.spec(0.002, 5);
    let data = spec.synth.generate();
    let model = train(
        &data,
        &TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: data.len() * 10,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(model.factor.landmarks.rows <= data.len());
}

#[test]
fn truncated_model_file_is_a_clean_error() {
    let spec = PaperDataset::Adult.spec(0.002, 6);
    let data = spec.synth.generate();
    let model = train(
        &data,
        &TrainConfig {
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let dir = temp_dir("model");
    let path = dir.join("full.lpd");
    lpdsvm::model::io::save(&model, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let cut = dir.join("cut.lpd");
    std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
    assert!(lpdsvm::model::io::load(&cut).is_err());
}

// ---------------------------------------------------------------------
// Deterministic fault-injection drills: the crash-safety and supervision
// claims, exercised by actually firing faults at the named boundaries.

fn sample_solution() -> Solution {
    Solution {
        alpha: vec![0.0, 0.5, 1.0],
        w: vec![0.25, -0.75],
        objective: -1.5,
        steps: 42,
        epochs: 3,
        sv_count: 2,
        converged: true,
        violation: 0.004,
        train_secs: 0.1,
        final_active: 3,
    }
}

#[test]
fn checkpoint_mid_write_crash_commits_nothing() {
    let _gate = fault::test_lock();
    let dir = temp_dir("ckpt_midwrite");
    let _ = std::fs::remove_file(dir.join("t.done.ckpt"));
    let ckpt = CheckpointCtx::new(&dir, 1).unwrap();
    // Fail between temp-write and rename: the atomic-replace discipline
    // means the committed path must simply not exist afterwards — a cold
    // start on resume, never a half-written checkpoint.
    fault::set_schedule("ckpt.after_tmp_write=error").unwrap();
    assert!(ckpt.store_solution("t", &sample_solution()).is_err());
    fault::clear();
    assert!(ckpt.load_solution("t").unwrap().is_none());
    // A clean retry commits and round-trips.
    ckpt.store_solution("t", &sample_solution()).unwrap();
    let back = ckpt.load_solution("t").unwrap().expect("committed");
    assert_eq!(back.alpha, sample_solution().alpha);
    assert_eq!(back.steps, 42);
}

#[test]
fn corrupted_checkpoint_is_an_error_not_a_silent_cold_start() {
    let dir = temp_dir("ckpt_corrupt");
    let ckpt = CheckpointCtx::new(&dir, 1).unwrap();
    ckpt.store_solution("t", &sample_solution()).unwrap();
    let path = dir.join("t.done.ckpt");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    // A bit-flipped checkpoint must refuse to resume, loudly — silently
    // restarting from zero would break the bit-identity contract without
    // anyone noticing.
    let err = ckpt.load_solution("t").unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");
}

#[test]
fn killed_training_run_resumes_bit_identical() {
    let bin = env!("CARGO_BIN_EXE_lpdsvm");
    let dir = temp_dir("kill_resume");
    let data = dir.join("data.svm");
    let base_model = dir.join("base.lpd");
    let resumed_model = dir.join("resumed.lpd");
    let ckpt_dir = dir.join("ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let _ = std::fs::remove_file(&base_model);
    let _ = std::fs::remove_file(&resumed_model);

    let run = |args: &[&str], faults: Option<&str>| {
        let mut cmd = std::process::Command::new(bin);
        cmd.args(args);
        match faults {
            Some(f) => cmd.env("LPDSVM_FAULTS", f),
            None => cmd.env_remove("LPDSVM_FAULTS"),
        };
        cmd.output().unwrap()
    };
    let gen = run(
        &[
            "gen-data", "--dataset", "adult", "--scale", "0.002", "--seed", "6",
            "--out", data.to_str().unwrap(),
        ],
        None,
    );
    assert!(gen.status.success(), "{}", String::from_utf8_lossy(&gen.stderr));

    // Tight eps keeps the solve multi-epoch, so checkpoint writes (one
    // snapshot per epoch, plus the completion record) number at least two.
    let train_args = |model_out: &str, with_ckpt: bool| {
        let mut a = vec![
            "train".to_string(), "--data".into(), data.to_str().unwrap().into(),
            "--model-out".into(), model_out.into(),
            "--budget".into(), "16".into(), "--eps".into(), "0.001".into(),
            "--seed".into(), "6".into(), "--threads".into(), "2".into(),
        ];
        if with_ckpt {
            a.extend([
                "--checkpoint".into(), ckpt_dir.to_str().unwrap().into(),
                "--checkpoint-every".into(), "1".into(),
            ]);
        }
        a
    };
    let to_refs = |a: &[String]| a.iter().map(|s| s.as_str()).collect::<Vec<_>>();

    // Reference: an uninterrupted, checkpoint-free run.
    let base_args = train_args(base_model.to_str().unwrap(), false);
    let base = run(&to_refs(&base_args), None);
    assert!(base.status.success(), "{}", String::from_utf8_lossy(&base.stderr));

    // The drill: abort the process mid-run, at the second checkpoint
    // write's temp-write/rename boundary (the honest stand-in for
    // SIGKILL), then re-invoke the identical command to resume.
    let ckpt_args = train_args(resumed_model.to_str().unwrap(), true);
    let killed = run(&to_refs(&ckpt_args), Some("ckpt.after_tmp_write=abort@2"));
    assert!(
        !killed.status.success(),
        "the injected abort must kill the run: {}",
        String::from_utf8_lossy(&killed.stdout)
    );
    assert!(!resumed_model.exists(), "no model may survive the abort");
    let resumed = run(&to_refs(&ckpt_args), None);
    assert!(resumed.status.success(), "{}", String::from_utf8_lossy(&resumed.stderr));

    // The killed-and-resumed model is bit-identical to the uninterrupted
    // one — resume replays the exact run, it does not approximate it.
    let a = std::fs::read(&base_model).unwrap();
    let b = std::fs::read(&resumed_model).unwrap();
    assert!(a == b, "resumed model differs from the uninterrupted run");
}

#[test]
fn serve_panic_storm_recovers_to_full_strength() {
    let _gate = fault::test_lock();
    // Two worker deaths, then three straight batch panics: the supervisor
    // must respawn both workers, the circuit breaker must quarantine the
    // model and recover it through a half-open probe, and the metrics
    // invariant must hold through all of it.
    fault::set_schedule("serve.worker=panic x2; serve.batch=panic x3").unwrap();
    let data = PaperDataset::Adult.spec(0.005, 9).synth.generate();
    let model = train(
        &data,
        &TrainConfig {
            stage1: Stage1Config {
                budget: 24,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    let expected = registry.get("m").unwrap().predict(&data.x).unwrap();
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            workers: 2,
            panic_quarantine_after: 3,
            quarantine_cooldown: Duration::from_millis(5),
            ..ServeConfig::default()
        },
    );
    let deadline = Instant::now() + Duration::from_secs(30);
    let m = engine.metrics();

    // Phase 1: both injected worker deaths happen on first poll; wait
    // until the supervisor has respawned back to full strength.
    while m.worker_restarts.load(Ordering::Relaxed) < 2 || engine.healthy_workers() < 2 {
        assert!(Instant::now() < deadline, "supervisor never restored full strength");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 2);

    // Phase 2: three sequential batches panic and trip the breaker.
    let row = data.x.row_entries(0);
    for _ in 0..3 {
        assert!(engine.submit("m", &row).wait().is_err());
    }
    while m.quarantines.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "breaker never opened");
        std::thread::sleep(Duration::from_millis(1));
    }

    // Phase 3: once the cooldown lapses, a submit is admitted as the
    // half-open probe; the fault budget is spent, so it scores cleanly
    // and closes the breaker.
    let ticket = loop {
        match engine.try_submit("m", &row) {
            Ok(t) => break t,
            Err(ServeError::ModelQuarantined { .. }) => {
                assert!(Instant::now() < deadline, "cooldown never elapsed");
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(other) => panic!("unexpected admission error: {other}"),
        }
    };
    assert_eq!(ticket.wait().unwrap().label, expected[0]);
    while m.quarantine_recoveries.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "breaker never closed");
        std::thread::sleep(Duration::from_millis(1));
    }

    // Phase 4: full strength — every subsequent request scores correctly.
    for i in 0..10 {
        let got = engine.submit("m", &data.x.row_entries(i)).wait().unwrap();
        assert_eq!(got.label, expected[i]);
    }
    assert_eq!(engine.healthy_workers(), 2);
    assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
    assert_eq!(m.quarantines.load(Ordering::Relaxed), 1);
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed),
        "accounting invariant broken after the storm"
    );
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
    engine.shutdown();
    fault::clear();
}
