//! Property tests for the out-of-core data plane (the blockwise
//! byte-identity contract):
//!
//! 1. Training through the streaming path produces **byte-identical**
//!    model files across block budgets {tiny, medium, ∞}, thread counts
//!    {1, 2, 8}, and sources (in-memory vs LIBSVM shards, at several
//!    shard counts). Block boundaries carry no information.
//! 2. A mid-block kill (fault-injected panic inside a checkpoint write)
//!    followed by a resume replays the exact trajectory of an
//!    uninterrupted solve — α, w, step counts and the reported KKT
//!    violation all match bitwise.

use lpdsvm::coordinator::checkpoint::CheckpointCtx;
use lpdsvm::coordinator::train::{train_streaming, TrainConfig};
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::data::{libsvm, DataSource, Dataset, MemorySource, ShardedSource};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{Stage1Config, StreamFactor};
use lpdsvm::model::io as model_io;
use lpdsvm::model::multiclass::MulticlassModel;
use lpdsvm::solver::{solve_blockwise, BlockProblem, SolverOptions};
use lpdsvm::util::fault;
use lpdsvm::util::timer::StageClock;
use std::path::{Path, PathBuf};

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lpdsvm_prop_block_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Dense features so the LIBSVM round-trip preserves the column count
/// (every column appears) and n > 2 stripes so small budgets really
/// produce multi-block epochs.
fn dataset(n: usize, seed: u64) -> Dataset {
    SynthSpec {
        name: "prop-block".into(),
        n,
        p: 12,
        n_classes: 2,
        sep: 1.5,
        latent: 4,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate()
}

fn train_cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        kernel: Kernel::gaussian(0.2),
        stage1: Stage1Config {
            budget: 24,
            ..Default::default()
        },
        solver: SolverOptions {
            eps: 1e-3,
            ..Default::default()
        },
        threads,
        compact_pairs: true,
    }
}

/// Serialize a model and return the file's exact bytes — the strongest
/// equality there is: rank, landmarks, whitening map, and every head
/// weight must agree bit for bit.
fn model_bytes(model: &MulticlassModel, dir: &Path, name: &str) -> Vec<u8> {
    let path = dir.join(name);
    model_io::save(model, &path).unwrap();
    std::fs::read(&path).unwrap()
}

#[test]
fn models_are_byte_identical_across_budgets_threads_and_sources() {
    let dir = temp_dir("identity");
    let data = dataset(2200, 7);
    let src = MemorySource::new(&data);

    let reference = {
        let model = train_streaming(&src, &train_cfg(0), 0, &mut StageClock::new(), None).unwrap();
        model_bytes(&model, &dir, "reference.lpd")
    };

    // Any block budget × any thread count — tiny (one stripe per block),
    // medium (a few stripes), and effectively-infinite budgets.
    for budget in [2_000usize, 50_000, 1 << 30] {
        for threads in [1usize, 2, 8] {
            let model =
                train_streaming(&src, &train_cfg(threads), budget, &mut StageClock::new(), None)
                    .unwrap();
            let bytes = model_bytes(&model, &dir, &format!("b{budget}_t{threads}.lpd"));
            assert_eq!(
                bytes, reference,
                "model diverged at budget {budget} threads {threads}"
            );
        }
    }

    // Shard the same data through the LIBSVM text round-trip: the on-disk
    // source must train the very same model, at any shard count.
    let svm = dir.join("data.svm");
    libsvm::write(&data, &svm).unwrap();
    for parts in [3usize, 7] {
        let shard_dir = dir.join(format!("shards{parts}"));
        libsvm::split_shards(&svm, &shard_dir, parts).unwrap();
        let sharded = ShardedSource::open(&shard_dir).unwrap();
        assert_eq!(sharded.n_rows(), data.len());
        let model =
            train_streaming(&sharded, &train_cfg(2), 2_000, &mut StageClock::new(), None).unwrap();
        let bytes = model_bytes(&model, &dir, &format!("shards{parts}.lpd"));
        assert_eq!(bytes, reference, "model diverged training from {parts} shards");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mid_block_kill_and_resume_matches_uninterrupted_solve() {
    let dir = temp_dir("kill_resume");
    let data = dataset(2200, 11);
    let src = MemorySource::new(&data);
    let factor = StreamFactor::compute(
        &src,
        Kernel::gaussian(0.2),
        &Stage1Config {
            budget: 24,
            ..Default::default()
        },
        0,
        &mut StageClock::new(),
    )
    .unwrap();
    let rows: Vec<usize> = (0..src.n_rows()).collect();
    let y: Vec<f32> = data
        .labels
        .iter()
        .map(|&l| if l == 1 { 1.0 } else { -1.0 })
        .collect();
    // Tiny budget → one stripe per block → several checkpoint writes per
    // epoch (one per block plus the epoch boundary).
    let p = BlockProblem::new(&src, &factor, rows, y, 2_000, NativeBackend::default());
    let opts = SolverOptions {
        eps: 1e-3,
        ..Default::default()
    };
    let reference = solve_blockwise(&p, &opts).unwrap();

    let ctx = CheckpointCtx::new(&dir, 1).unwrap();
    {
        let _gate = fault::test_lock();
        // The 2nd checkpoint write of the run lands mid-epoch (the first
        // epoch spans 3 blocks) — the panic kills the solve with a
        // partially-advanced stripe cursor on disk.
        fault::set_schedule("ckpt.after_tmp_write=panic@2").unwrap();
        let killed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ctx.solve_blockwise("drill", &p, &opts)
        }));
        fault::clear();
        assert!(killed.is_err(), "injected fault did not kill the solve");
    }
    assert!(
        dir.join("drill.ckpt").exists(),
        "the kill left no snapshot to resume from"
    );

    let resumed = ctx.solve_blockwise("drill", &p, &opts).unwrap();
    assert_eq!(resumed.alpha, reference.alpha, "alpha diverged after resume");
    assert_eq!(resumed.w, reference.w, "w diverged after resume");
    assert_eq!(resumed.steps, reference.steps, "step count diverged after resume");
    assert_eq!(resumed.violation, reference.violation);
    assert_eq!(resumed.objective, reference.objective);

    // A second call short-circuits to the recorded solution.
    let replay = ctx.solve_blockwise("drill", &p, &opts).unwrap();
    assert_eq!(replay.alpha, reference.alpha);
    let _ = std::fs::remove_dir_all(&dir);
}
