//! Integration tests for the HTTP front-end: a listener on an ephemeral
//! port, predictions identical to the in-process engine path, health and
//! metrics endpoints, keep-alive, and error/unavailability mapping.
//!
//! Every scenario runs under **both** io models — the bounded
//! thread-per-connection pool and the single epoll event loop — via a
//! shared scenario function and one `#[test]` wrapper per model. A
//! differential test additionally asserts the two models produce
//! byte-identical wire responses on deterministic endpoints.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::serve::{HttpOptions, HttpServer, IoModel, ModelRegistry, ServeConfig, ServeEngine};
use lpdsvm::util::json::{self, Json};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

fn dataset(seed: u64) -> Dataset {
    SynthSpec {
        name: "serve-http".into(),
        n: 180,
        p: 10,
        n_classes: 3,
        sep: 5.0,
        latent: 4,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate()
}

fn engine_only(seed: u64) -> (Dataset, Vec<u32>, Arc<ServeEngine>) {
    let data = dataset(seed);
    let cfg = TrainConfig {
        stage1: Stage1Config {
            budget: 24,
            ..Default::default()
        },
        ..Default::default()
    };
    let model = train(&data, &cfg).unwrap();
    let expected = model.predict(&data.x).unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    let engine = Arc::new(ServeEngine::start(
        registry,
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ..ServeConfig::default()
        },
    ));
    (data, expected, engine)
}

fn bind_with(engine: &Arc<ServeEngine>, io: IoModel, max_connections: usize) -> HttpServer {
    // Port 0: the OS picks a free ephemeral port; read it back via addr().
    HttpServer::bind_with_opts(
        Arc::clone(engine),
        "127.0.0.1:0",
        HttpOptions {
            io_model: io,
            max_connections,
            ..HttpOptions::default()
        },
    )
    .unwrap()
}

fn served_engine_with(seed: u64, io: IoModel) -> (Dataset, Vec<u32>, Arc<ServeEngine>, HttpServer) {
    let (data, expected, engine) = engine_only(seed);
    let max_connections = HttpOptions::default().max_connections;
    let server = bind_with(&engine, io, max_connections);
    (data, expected, engine, server)
}

/// Minimal HTTP/1.1 client: one request per connection (`connection:
/// close`), returns (status, body).
fn http_call(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

/// Read one length-framed response off a (possibly keep-alive) stream.
fn read_response<R: BufRead>(reader: &mut R) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .unwrap();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// One tolerant `GET /healthz` probe: `Ok(true)` iff the server answered
/// 200. IO errors (resets from a still-capped listener) surface as `Err`
/// for the caller to retry.
fn healthz_ok(addr: SocketAddr) -> std::io::Result<bool> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")?;
    let mut status_line = String::new();
    BufReader::new(stream).read_line(&mut status_line)?;
    Ok(status_line.contains(" 200 "))
}

/// Encode sparse rows as the predict-endpoint batch body.
fn rows_body(rows: &[Vec<(u32, f32)>]) -> String {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::Arr(
                r.iter()
                    .map(|&(c, v)| json::arr(vec![json::unum(c as u64), json::num(v as f64)]))
                    .collect(),
            )
        })
        .collect();
    json::obj(vec![("rows", Json::Arr(rows_json))]).to_string()
}

fn labels_of(response_body: &str) -> Vec<u32> {
    let v = Json::parse(response_body).unwrap();
    v.get("predictions")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.get("label").expect("prediction, not error").as_u64().unwrap() as u32)
        .collect()
}

fn predictions_scenario(io: IoModel) {
    let (data, expected, engine, server) = served_engine_with(41, io);
    let rows: Vec<Vec<(u32, f32)>> = (0..data.len()).map(|i| data.x.row_entries(i)).collect();

    // In-process path.
    let in_process: Vec<u32> = rows
        .iter()
        .map(|r| engine.submit("m", r).wait().unwrap().label)
        .collect();
    assert_eq!(in_process, expected);

    // Same workload over HTTP, in batch POSTs of 60 rows.
    let mut over_http = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(60) {
        let (status, body) =
            http_call(server.addr(), "POST", "/v1/models/m:predict", Some(&rows_body(chunk)));
        assert_eq!(status, 200, "body: {body}");
        over_http.extend(labels_of(&body));
    }
    assert_eq!(over_http, expected, "HTTP must be byte-identical to in-process");

    // Single-row form.
    let single = json::obj(vec![(
        "row",
        Json::Arr(
            rows[0]
                .iter()
                .map(|&(c, v)| json::arr(vec![json::unum(c as u64), json::num(v as f64)]))
                .collect(),
        ),
    )])
    .to_string();
    let (status, body) = http_call(server.addr(), "POST", "/v1/models/m:predict", Some(&single));
    assert_eq!(status, 200);
    assert_eq!(labels_of(&body), vec![expected[0]]);

    server.shutdown();
    engine.shutdown();
}

#[test]
fn http_predictions_match_in_process_engine() {
    predictions_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn http_predictions_match_in_process_engine_evented() {
    predictions_scenario(IoModel::Evented);
}

fn healthz_metrics_scenario(io: IoModel) {
    let (data, _expected, engine, server) = served_engine_with(42, io);
    let addr = server.addr();

    let (status, body) = http_call(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "body: {body}");
    let health = Json::parse(&body).unwrap();
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(health.get("healthy_workers").unwrap().as_u64().unwrap() >= 1);
    assert_eq!(health.get("models").unwrap().as_u64().unwrap(), 1);

    let (status, body) = http_call(addr, "GET", "/v1/models", None);
    assert_eq!(status, 200);
    let listing = Json::parse(&body).unwrap();
    assert_eq!(listing.get("count").unwrap().as_u64().unwrap(), 1);
    assert_eq!(
        listing.get("models").unwrap().as_arr().unwrap()[0]
            .as_str()
            .unwrap(),
        "m"
    );

    // Score one row so the counters move, then check both metric formats.
    let row = data.x.row_entries(0);
    let (status, _) = http_call(addr, "POST", "/v1/models/m:predict", Some(&rows_body(&[row])));
    assert_eq!(status, 200);
    let (status, body) = http_call(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let metrics = Json::parse(&body).unwrap();
    let submitted = metrics.get("submitted").unwrap().as_u64().unwrap();
    assert!(submitted >= 1);
    // Quiesced (every response arrived) ⇒ nothing in flight.
    assert_eq!(
        submitted,
        metrics.get("completed").unwrap().as_u64().unwrap()
            + metrics.get("failed").unwrap().as_u64().unwrap()
            + metrics.get("queue_depth").unwrap().as_u64().unwrap()
    );
    assert!(metrics.get("latency_us").unwrap().get("p99").is_some());
    let (status, body) = http_call(addr, "GET", "/metrics?format=table", None);
    assert_eq!(status, 200);
    assert!(body.contains("requests submitted"), "table body: {body}");

    // Prometheus exposition: same counter values as the JSON snapshot,
    // with per-model labels for the tenant buckets.
    let (status, body) = http_call(addr, "GET", "/metrics?format=prometheus", None);
    assert_eq!(status, 200);
    assert!(
        body.contains("# TYPE lpdsvm_serve_submitted_total counter"),
        "prometheus body: {body}"
    );
    assert!(
        body.contains(&format!("lpdsvm_serve_submitted_total {submitted}\n")),
        "prometheus body: {body}"
    );
    assert!(
        body.contains("lpdsvm_serve_model_submitted_total{model=\"m\"}"),
        "prometheus body: {body}"
    );
    assert!(
        body.contains("lpdsvm_serve_latency_us_bucket"),
        "prometheus body: {body}"
    );
    assert!(
        body.contains("lpdsvm_serve_queue_wait_us_count"),
        "prometheus body: {body}"
    );

    server.shutdown();
    engine.shutdown();
}

#[test]
fn healthz_metrics_and_model_listing() {
    healthz_metrics_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn healthz_metrics_and_model_listing_evented() {
    healthz_metrics_scenario(IoModel::Evented);
}

fn keep_alive_scenario(io: IoModel) {
    let (_data, _expected, engine, server) = served_engine_with(43, io);
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    for (i, connection) in ["keep-alive", "close"].iter().enumerate() {
        let req = format!(
            "GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: {connection}\r\n\r\n"
        );
        writer.write_all(req.as_bytes()).unwrap();
        let (status, body) = read_response(&mut reader);
        assert_eq!(status, 200, "request {i}: {body}");
    }
    // Server honoured `connection: close` — the stream now yields EOF.
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());

    server.shutdown();
    engine.shutdown();
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    keep_alive_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn keep_alive_serves_sequential_requests_on_one_connection_evented() {
    keep_alive_scenario(IoModel::Evented);
}

fn expect_continue_scenario(io: IoModel) {
    let (data, expected, engine, server) = served_engine_with(45, io);
    let row = data.x.row_entries(0);
    let body = rows_body(&[row]);

    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let req = format!(
        "POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\nexpect: 100-continue\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    // Interim go-ahead first (curl stalls ~1 s per request without it)…
    let (interim, _) = read_response(&mut reader);
    assert_eq!(interim, 100);
    // …then the real response.
    let (status, resp) = read_response(&mut reader);
    assert_eq!(status, 200, "body: {resp}");
    assert_eq!(labels_of(&resp), vec![expected[0]]);

    server.shutdown();
    engine.shutdown();
}

#[test]
fn expect_100_continue_gets_interim_response() {
    expect_continue_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn expect_100_continue_gets_interim_response_evented() {
    expect_continue_scenario(IoModel::Evented);
}

fn put_config_scenario(io: IoModel) {
    let (data, _expected, engine, server) = served_engine_with(46, io);
    let addr = server.addr();

    // Update the registered model's scheduler policy.
    let (status, body) = http_call(
        addr,
        "PUT",
        "/v1/models/m:config",
        Some(r#"{"weight": 3, "max_queue": 8}"#),
    );
    assert_eq!(status, 200, "body: {body}");
    let cfg = Json::parse(&body).unwrap();
    assert_eq!(cfg.get("weight").unwrap().as_u64(), Some(3));
    assert_eq!(cfg.get("max_queue").unwrap().as_u64(), Some(8));
    assert_eq!(engine.registry().serve_config("m").weight, 3);

    // Omitted fields keep their value; null clears the queue override.
    let (status, body) =
        http_call(addr, "PUT", "/v1/models/m:config", Some(r#"{"max_queue": null}"#));
    assert_eq!(status, 200, "body: {body}");
    let cfg = Json::parse(&body).unwrap();
    assert_eq!(cfg.get("weight").unwrap().as_u64(), Some(3), "weight kept");
    assert!(matches!(cfg.get("max_queue"), Some(Json::Null)));

    // Invalid values and unknown names are rejected without side effects.
    let (status, _) = http_call(addr, "PUT", "/v1/models/m:config", Some(r#"{"weight": 0}"#));
    assert_eq!(status, 400);
    let (status, _) = http_call(addr, "PUT", "/v1/models/m:config", Some(r#"{"weight": 1.5}"#));
    assert_eq!(status, 400);
    let (status, body) =
        http_call(addr, "PUT", "/v1/models/ghost:config", Some(r#"{"weight": 2}"#));
    assert_eq!(status, 404, "body: {body}");
    assert_eq!(engine.registry().serve_config("m").weight, 3, "unchanged");

    // Score one row, then check the per_model metrics section.
    let row = data.x.row_entries(0);
    let (status, _) = http_call(addr, "POST", "/v1/models/m:predict", Some(&rows_body(&[row])));
    assert_eq!(status, 200);
    let (status, body) = http_call(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let metrics = Json::parse(&body).unwrap();
    let per_model = metrics.get("per_model").unwrap();
    let m = per_model.get("m").unwrap();
    assert_eq!(m.get("weight").unwrap().as_u64(), Some(3));
    assert!(m.get("submitted").unwrap().as_u64().unwrap() >= 1);
    // Per-model invariant holds at quiescence, mirroring the global one.
    assert_eq!(
        m.get("submitted").unwrap().as_u64().unwrap(),
        m.get("completed").unwrap().as_u64().unwrap()
            + m.get("failed").unwrap().as_u64().unwrap()
            + m.get("queue_depth").unwrap().as_u64().unwrap()
    );
    assert!(m.get("latency_us").unwrap().get("p99").is_some());

    server.shutdown();
    engine.shutdown();
}

#[test]
fn put_config_updates_weight_and_metrics_expose_per_model() {
    put_config_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn put_config_updates_weight_and_metrics_expose_per_model_evented() {
    put_config_scenario(IoModel::Evented);
}

fn connection_cap_scenario(io: IoModel) {
    let (_data, _expected, engine) = engine_only(47);
    // A dedicated listener with a single-connection budget.
    let server = bind_with(&engine, io, 1);
    let addr = server.addr();

    // Occupy the only slot with a keep-alive connection; completing one
    // request proves the connection is up and counted.
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer
        .write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: keep-alive\r\n\r\n")
        .unwrap();
    let (status, _) = read_response(&mut reader);
    assert_eq!(status, 200);

    // A second connection is over the cap: the server answers 503 and
    // closes without ever reading a request. Probe read-only — writing a
    // request that races the server-side close could RST away the
    // buffered response.
    let probe = TcpStream::connect(addr).unwrap();
    probe.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut probe_reader = BufReader::new(probe);
    let (status, body) = read_response(&mut probe_reader);
    assert_eq!(status, 503, "body: {body}");
    assert!(body.contains("connection limit"), "body: {body}");

    // Release the slot; the server recovers once it notices the close
    // (poll briefly — the decrement is asynchronous, and probes that
    // still hit the cap may see resets: tolerate them).
    drop(reader);
    drop(writer);
    let t0 = std::time::Instant::now();
    loop {
        if healthz_ok(addr).unwrap_or(false) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "connection slot never freed after client close"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    server.shutdown();
    engine.shutdown();
}

#[test]
fn connection_cap_503s_excess_connections_and_recovers() {
    connection_cap_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn connection_cap_503s_excess_connections_and_recovers_evented() {
    connection_cap_scenario(IoModel::Evented);
}

fn error_mapping_scenario(io: IoModel) {
    let (data, _expected, engine, server) = served_engine_with(44, io);
    let addr = server.addr();
    let row = data.x.row_entries(0);

    let (status, body) = http_call(addr, "GET", "/nope", None);
    assert_eq!(status, 404, "body: {body}");
    let (status, body) = http_call(addr, "POST", "/v1/models/m:predict", Some("{not json"));
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("invalid JSON"));
    let (status, body) = http_call(addr, "POST", "/v1/models/m:predict", Some(r#"{"x": 1}"#));
    assert_eq!(status, 400, "body: {body}");
    let (status, body) = http_call(
        addr,
        "POST",
        "/v1/models/ghost:predict",
        Some(&rows_body(&[row.clone()])),
    );
    assert_eq!(status, 400, "unknown model is a client error; body: {body}");
    assert!(body.contains("not registered"));

    // Engine gone, front-end still up: predicts become 503 (retryable),
    // introspection endpoints keep answering.
    engine.shutdown();
    let (status, body) = http_call(addr, "POST", "/v1/models/m:predict", Some(&rows_body(&[row])));
    assert_eq!(status, 503, "body: {body}");
    assert!(body.contains("shut down"));
    let (status, _) = http_call(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);

    server.shutdown();
}

#[test]
fn error_mapping_bad_input_unknown_model_and_shutdown() {
    error_mapping_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn error_mapping_bad_input_unknown_model_and_shutdown_evented() {
    error_mapping_scenario(IoModel::Evented);
}

/// Write one raw request and capture the complete wire response (the
/// request carries `connection: close`, so EOF frames it).
#[cfg(target_os = "linux")]
fn raw_call(addr: SocketAddr, request: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(request).unwrap();
    let mut out = Vec::new();
    stream.read_to_end(&mut out).unwrap();
    out
}

/// The headline tentpole guarantee: for any request whose response does
/// not embed timing fields, the evented loop produces **byte-identical**
/// wire output to the threaded model — same status line, same headers,
/// same body, same framing. Both servers share one engine so dynamic
/// state (worker counts, registry) cannot diverge.
#[cfg(target_os = "linux")]
#[test]
fn evented_and_threaded_responses_are_byte_identical() {
    let (data, expected, engine) = engine_only(48);
    let max_connections = HttpOptions::default().max_connections;
    let threaded = bind_with(&engine, IoModel::Threads, max_connections);
    let evented = bind_with(&engine, IoModel::Evented, max_connections);

    let predict_bad = "{not json";
    let ghost = rows_body(&[data.x.row_entries(0)]);
    // A newline-free header line at exactly the cap: both models must
    // reject with the same 400, and the exact sizing means the server
    // consumes every sent byte before closing (clean close, no reset).
    let cap = lpdsvm::serve::http::MAX_HEADER_LINE as usize;
    let mut long_header = b"GET /healthz HTTP/1.1\r\nx-junk: ".to_vec();
    long_header.extend(vec![b'a'; cap - "x-junk: ".len()]);
    let cases: Vec<Vec<u8>> = vec![
        b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n".to_vec(),
        b"GET /v1/models HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n".to_vec(),
        b"GET /nope HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n".to_vec(),
        b"DELETE /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n".to_vec(),
        format!(
            "POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{predict_bad}",
            predict_bad.len()
        )
        .into_bytes(),
        format!(
            "POST /v1/models/ghost:predict HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{ghost}",
            ghost.len()
        )
        .into_bytes(),
        b"POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n"
            .to_vec(),
        long_header,
    ];
    for case in &cases {
        let from_threads = raw_call(threaded.addr(), case);
        let from_evented = raw_call(evented.addr(), case);
        assert!(
            !from_threads.is_empty(),
            "no response for {:?}",
            String::from_utf8_lossy(case)
        );
        assert_eq!(
            from_threads,
            from_evented,
            "wire bytes diverge for request {:?}:\n threads: {:?}\n evented: {:?}",
            String::from_utf8_lossy(case),
            String::from_utf8_lossy(&from_threads),
            String::from_utf8_lossy(&from_evented)
        );
    }

    // Successful predict bodies embed queue/total timing that varies per
    // run, so compare the decision-relevant content: status and labels.
    let body = rows_body(&(0..8).map(|i| data.x.row_entries(i)).collect::<Vec<_>>());
    let (ts, tb) = http_call(threaded.addr(), "POST", "/v1/models/m:predict", Some(&body));
    let (es, eb) = http_call(evented.addr(), "POST", "/v1/models/m:predict", Some(&body));
    assert_eq!((ts, es), (200, 200), "threads: {tb}\nevented: {eb}");
    assert_eq!(labels_of(&tb), expected[..8].to_vec());
    assert_eq!(labels_of(&eb), expected[..8].to_vec());

    threaded.shutdown();
    evented.shutdown();
    engine.shutdown();
}
