//! Property-based tests over the solver and stage-1 invariants
//! (DESIGN.md §5), using the in-repo property-testing framework
//! (`lpdsvm::testing`) — proptest is unavailable offline.

use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::dense::dot;
use lpdsvm::linalg::eigen::sym_eig;
use lpdsvm::linalg::Mat;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{LowRankFactor, Stage1Config};
use lpdsvm::solver::{solve, ProblemView, SolverOptions};
use lpdsvm::testing::prop::{forall, usize_in, Gen};
use lpdsvm::util::rng::Rng;
use lpdsvm::util::timer::StageClock;

/// A random linear-SVM problem instance (G features + labels + C).
#[derive(Clone, Debug)]
struct RandomProblem {
    n: usize,
    dim: usize,
    c: f64,
    noise: f64,
    seed: u64,
}

fn problem_gen() -> Gen<RandomProblem> {
    Gen::new(
        |rng: &mut Rng| RandomProblem {
            n: 10 + rng.usize(150),
            dim: 1 + rng.usize(16),
            c: [0.1, 0.5, 1.0, 4.0, 32.0][rng.usize(5)],
            noise: rng.f64() * 0.2,
            seed: rng.next_u64(),
        },
        |p| {
            let mut shrunk = Vec::new();
            if p.n > 10 {
                shrunk.push(RandomProblem { n: 10 + (p.n - 10) / 2, ..p.clone() });
            }
            if p.dim > 1 {
                shrunk.push(RandomProblem { dim: 1 + (p.dim - 1) / 2, ..p.clone() });
            }
            if p.noise > 0.0 {
                shrunk.push(RandomProblem { noise: 0.0, ..p.clone() });
            }
            shrunk
        },
    )
}

fn materialise(p: &RandomProblem) -> (Mat, Vec<usize>, Vec<f32>) {
    let mut rng = Rng::new(p.seed);
    let mut g = Mat::zeros(p.n, p.dim);
    let mut y = Vec::with_capacity(p.n);
    for i in 0..p.n {
        let cls = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        for j in 0..p.dim {
            let mean = if j == 0 { cls * 1.5 } else { 0.0 };
            g.set(i, j, mean + rng.normal() as f32 * 0.5);
        }
        let label = if rng.bool(p.noise) { -cls } else { cls };
        y.push(label);
    }
    (g, (0..p.n).collect(), y)
}

#[test]
fn prop_alpha_always_in_box() {
    forall("alpha-in-box", 40, &problem_gen(), |p| {
        let (g, rows, y) = materialise(p);
        let view = ProblemView::new(&g, &rows, &y);
        let sol = solve(
            &view,
            &SolverOptions {
                c: p.c,
                seed: p.seed,
                ..Default::default()
            },
        );
        for (i, &a) in sol.alpha.iter().enumerate() {
            if !(0.0..=p.c as f32 + 1e-6).contains(&a) {
                return Err(format!("alpha[{i}] = {a} outside [0, {}]", p.c));
            }
            if !a.is_finite() {
                return Err(format!("alpha[{i}] not finite"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kkt_holds_at_convergence() {
    forall("kkt-at-convergence", 25, &problem_gen(), |p| {
        let (g, rows, y) = materialise(p);
        let view = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            c: p.c,
            eps: 1e-3,
            max_epochs: 5000,
            seed: p.seed,
            ..Default::default()
        };
        let sol = solve(&view, &opts);
        if !sol.converged {
            // Not a failure per se (epoch cap) but must self-report.
            return if sol.violation >= 1e-3 {
                Ok(())
            } else {
                Err("not converged but violation < eps".into())
            };
        }
        for i in 0..view.len() {
            let grad = y[i] * dot(view.feature_row(i), &sol.w) - 1.0;
            let viol = if sol.alpha[i] <= 0.0 {
                (-grad).max(0.0)
            } else if sol.alpha[i] >= p.c as f32 {
                grad.max(0.0)
            } else {
                grad.abs()
            };
            // The stopping rule samples each variable's violation at its
            // visit time within the final epoch; later updates can nudge
            // earlier gradients (same semantics as LIBLINEAR), so allow a
            // small multiple of eps here.
            if viol > 5e-3 {
                return Err(format!("KKT violated at {i}: {viol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shrinking_reaches_same_objective() {
    forall("shrink-same-objective", 20, &problem_gen(), |p| {
        let (g, rows, y) = materialise(p);
        let view = ProblemView::new(&g, &rows, &y);
        let base = SolverOptions {
            c: p.c,
            eps: 1e-4,
            max_epochs: 5000,
            seed: p.seed,
            ..Default::default()
        };
        let with = solve(&view, &base);
        let without = solve(
            &view,
            &SolverOptions {
                shrinking: false,
                ..base
            },
        );
        let tol = 5e-3 * (1.0 + without.objective.abs());
        if (with.objective - without.objective).abs() > tol {
            return Err(format!(
                "objectives differ: {} vs {}",
                with.objective, without.objective
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_warm_start_matches_cold_start() {
    forall("warm-equals-cold", 15, &problem_gen(), |p| {
        let (g, rows, y) = materialise(p);
        let view = ProblemView::new(&g, &rows, &y);
        let small = solve(
            &view,
            &SolverOptions {
                c: p.c * 0.5,
                eps: 1e-4,
                seed: p.seed,
                ..Default::default()
            },
        );
        let opts_big = SolverOptions {
            c: p.c,
            eps: 1e-4,
            seed: p.seed,
            ..Default::default()
        };
        let cold = solve(&view, &opts_big);
        let warm = solve(
            &view,
            &SolverOptions {
                warm_alpha: Some(small.alpha),
                ..opts_big
            },
        );
        let tol = 5e-3 * (1.0 + cold.objective.abs());
        if (warm.objective - cold.objective).abs() > tol {
            return Err(format!(
                "warm {} vs cold {}",
                warm.objective, cold.objective
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_objective_monotone_along_c_path() {
    forall("objective-monotone-c", 15, &problem_gen(), |p| {
        let (g, rows, y) = materialise(p);
        let view = ProblemView::new(&g, &rows, &y);
        let mut last = -f64::MAX;
        for mult in [0.25, 0.5, 1.0, 2.0] {
            let sol = solve(
                &view,
                &SolverOptions {
                    c: p.c * mult,
                    eps: 1e-5,
                    max_epochs: 5000,
                    seed: p.seed,
                    ..Default::default()
                },
            );
            if sol.objective < last - 1e-5 * (1.0 + last.abs()) {
                return Err(format!(
                    "objective dropped from {last} to {} at C×{mult}",
                    sol.objective
                ));
            }
            last = sol.objective;
        }
        Ok(())
    });
}

/// Stage-1 invariant: the Nyström approximation `G Gᵀ` is PSD and matches
/// the exact kernel on landmark pairs.
#[test]
fn prop_nystrom_psd_and_exact_on_landmarks() {
    forall("nystrom-psd", 12, &usize_in(20, 80), |&n| {
        let mut rng = Rng::new(n as u64 * 31 + 5);
        let mut rows = Vec::new();
        for _ in 0..n {
            let mut row = Vec::new();
            for c in 0..8u32 {
                if rng.bool(0.7) {
                    row.push((c, rng.normal() as f32));
                }
            }
            rows.push(row);
        }
        let x = lpdsvm::data::sparse::SparseMatrix::from_rows(8, &rows);
        let kernel = Kernel::gaussian(0.2);
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(
            &x,
            kernel,
            &Stage1Config {
                budget: n / 2,
                ..Default::default()
            },
            &NativeBackend::default(),
            &mut clock,
        )
        .map_err(|e| e.to_string())?;
        // PSD: eigenvalues of the n×n approx matrix are >= -tol.
        let approx = factor.g.matmul_nt(&factor.g);
        let eig = sym_eig(&approx, 40, 1e-10);
        if let Some(&lmin) = eig.values.last() {
            if lmin < -1e-3 {
                return Err(format!("G Gᵀ not PSD: λ_min = {lmin}"));
            }
        }
        // Exactness on landmark pairs.
        for (ai, &i) in factor.landmark_idx.iter().enumerate().step_by(7) {
            for &j in factor.landmark_idx.iter().skip(ai).step_by(11) {
                let exact = kernel.eval_sparse(&x, i, &x, j);
                let approx = factor.approx_kernel(i, j);
                if (exact - approx).abs() > 5e-3 {
                    return Err(format!(
                        "Nyström not exact on landmarks ({i},{j}): {exact} vs {approx}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Jacobi eigensolver invariant on random Gram matrices.
#[test]
fn prop_jacobi_reconstructs_gram_matrices() {
    forall("jacobi-reconstruction", 20, &usize_in(2, 24), |&n| {
        let mut rng = Rng::new(n as u64 * 97 + 3);
        let x = Mat::from_fn(n, n + 2, |_, _| rng.normal() as f32);
        let a = x.matmul_nt(&x);
        let e = sym_eig(&a, 50, 1e-12);
        // A v_k = λ_k v_k
        for k in 0..n {
            let v: Vec<f32> = (0..n).map(|i| e.vectors.at(i, k)).collect();
            let av = a.matvec(&v);
            for i in 0..n {
                let want = e.values[k] as f32 * v[i];
                let scale = 1.0 + e.values[0].abs() as f32;
                if (av[i] - want).abs() > 1e-3 * scale {
                    return Err(format!(
                        "eigen equation fails at k={k} i={i}: {} vs {want}",
                        av[i]
                    ));
                }
            }
        }
        Ok(())
    });
}
