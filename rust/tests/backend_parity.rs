//! Native vs PJRT backend parity — the cross-layer contract of the whole
//! three-layer design. Skipped (with a message) if artifacts are missing.

use lpdsvm::coordinator::train::{train_with_backend, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::runtime::{AccelBackend, Runtime};
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::timer::StageClock;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Runtime::load(&dir).expect("artifacts present but unloadable"))
    } else {
        eprintln!("skipping backend parity: run `make artifacts` first");
        None
    }
}

#[test]
fn full_training_agrees_across_backends() {
    let Some(rt) = runtime() else { return };
    for ds in [PaperDataset::Adult, PaperDataset::Susy] {
        let spec = ds.spec(0.002, 21);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: spec.budget.min(512),
                chunk: 256,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c1 = StageClock::new();
        let m_native = train_with_backend(&data, &cfg, &NativeBackend::default(), &mut c1).unwrap();
        let accel = AccelBackend::new(&rt);
        let mut c2 = StageClock::new();
        let m_accel = train_with_backend(&data, &cfg, &accel, &mut c2).unwrap();

        let g_diff = m_native.factor.g.max_abs_diff(&m_accel.factor.g);
        assert!(g_diff < 5e-3, "{}: G diff {g_diff}", ds.name());
        // Predictions must agree on (almost) every point.
        let p1 = m_native.predict(&data.x).unwrap();
        let p2 = m_accel.predict(&data.x).unwrap();
        let disagree = p1.iter().zip(&p2).filter(|(a, b)| a != b).count();
        assert!(
            (disagree as f64) < 0.01 * data.len() as f64,
            "{}: {} of {} predictions disagree",
            ds.name(),
            disagree,
            data.len()
        );
    }
}

#[test]
fn transform_matches_for_fresh_data() {
    let Some(rt) = runtime() else { return };
    let spec = PaperDataset::Epsilon.spec(0.0005, 23);
    let data = spec.synth.generate();
    let cfg = Stage1Config {
        budget: 96,
        chunk: 256,
        ..Default::default()
    };
    let kernel = Kernel::gaussian(spec.gamma);
    let mut clock = StageClock::new();
    let factor = lpdsvm::lowrank::LowRankFactor::compute(
        &data.x,
        kernel,
        &cfg,
        &NativeBackend::default(),
        &mut clock,
    )
    .unwrap();
    // Fresh data through both transform paths.
    let fresh = PaperDataset::Epsilon.spec(0.0003, 99).synth.generate();
    let g_native = factor.transform(&fresh.x, &NativeBackend::default(), 256).unwrap();
    let accel = AccelBackend::new(&rt);
    let g_accel = factor.transform(&fresh.x, &accel, 256).unwrap();
    let diff = g_native.max_abs_diff(&g_accel);
    assert!(diff < 5e-3, "transform diff {diff}");
}

#[test]
fn artifact_variant_selection_is_minimal() {
    let Some(rt) = runtime() else { return };
    // p=123-style input must NOT pick the p=2560 variant.
    let a = rt.pick_stage1(64, 123).expect("variant for p=123");
    assert_eq!(a.p, 128, "picked {:?}", (a.b, a.p));
    assert_eq!(a.b, 128);
    let b = rt.pick_stage1(200, 1500).expect("variant for b=200,p=1500");
    assert_eq!(b.b, 512);
    assert_eq!(b.p, 2560);
    // Oversized request has no variant.
    assert!(rt.pick_stage1(10_000, 10).is_none());
}
