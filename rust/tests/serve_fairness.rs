//! Multi-tenant fairness regression tests: a hot model saturating its own
//! bounded sub-queue must never shed or starve a cold model, per-model
//! metric invariants must hold under shedding on both policies, and
//! registry-lifecycle operations (config updates, removal) must interact
//! cleanly with the scheduler.
//!
//! Determinism comes from a gated stage-1 backend: the sole worker blocks
//! on a gate while the tests fill per-model queues to exact depths, then
//! the gate opens and everything drains.

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::Mat;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{Stage1Backend, Stage1Config};
use lpdsvm::model::multiclass::MulticlassModel;
use lpdsvm::serve::{
    BackendProvider, ModelMetrics, ModelRegistry, ModelServeConfig, ServeConfig, ServeEngine,
    ServeError, ServeMetrics, ShedPolicy,
};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

fn binary_dataset(seed: u64) -> Dataset {
    PaperDataset::Adult.spec(0.005, seed).synth.generate()
}

fn quick_train(data: &Dataset) -> MulticlassModel {
    let cfg = TrainConfig {
        stage1: Stage1Config {
            budget: 24,
            ..Default::default()
        },
        ..Default::default()
    };
    train(data, &cfg).unwrap()
}

/// Registry serving the same trained model under both tenant names.
fn two_tenant_registry(seed: u64) -> (Dataset, Arc<ModelRegistry>) {
    let data = binary_dataset(seed);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("hot", quick_train(&data));
    let shared = Arc::clone(registry.get("hot").unwrap().model());
    registry.insert_arc("cold", shared);
    (data, registry)
}

/// A [`Stage1Backend`] that blocks every scoring call on a shared gate —
/// the deterministic way to hold the worker busy while queues fill.
struct GatedBackend {
    inner: NativeBackend,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Stage1Backend for GatedBackend {
    fn g_chunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        landmarks: &Mat,
        landmark_sq: &[f32],
        whiten: &Mat,
        kernel: &Kernel,
    ) -> anyhow::Result<Mat> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        self.inner.g_chunk(x, rows, landmarks, landmark_sq, whiten, kernel)
    }

    fn name(&self) -> &'static str {
        "gated-native"
    }
}

struct GatedProvider {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl BackendProvider for GatedProvider {
    fn backend(&self) -> anyhow::Result<Box<dyn Stage1Backend + '_>> {
        Ok(Box::new(GatedBackend {
            inner: NativeBackend::default(),
            gate: Arc::clone(&self.gate),
        }))
    }
}

fn gated_engine(
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
) -> (ServeEngine, Arc<(Mutex<bool>, Condvar)>) {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let engine = ServeEngine::start_with_provider(
        registry,
        cfg,
        Arc::new(GatedProvider {
            gate: Arc::clone(&gate),
        }),
    );
    (engine, gate)
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (lock, cv) = &**gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

/// Block until the engine has dispatched at least `n` batches (i.e. the
/// gated worker has pulled work off the queues).
fn wait_for_batches(metrics: &ServeMetrics, n: u64) {
    let t0 = Instant::now();
    while metrics.batches.load(Ordering::Relaxed) < n {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never dispatched");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// `submitted == completed + failed + in-flight` for one tenant bucket.
/// `queue_depth` counts only undispatched requests, so callers pass the
/// number of dispatched-but-unresolved requests (e.g. a batch blocked on
/// the gate) as `dispatched`; at quiescence it is 0.
fn assert_bucket_invariant(b: &ModelMetrics, who: &str, dispatched: u64) {
    assert_eq!(
        b.submitted.load(Ordering::Relaxed),
        b.completed.load(Ordering::Relaxed)
            + b.failed.load(Ordering::Relaxed)
            + b.queue_depth.load(Ordering::Relaxed)
            + dispatched,
        "per-model invariant broken for '{who}'"
    );
}

fn assert_global_invariant(m: &ServeMetrics, dispatched: u64) {
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed)
            + m.failed.load(Ordering::Relaxed)
            + m.queue_depth.load(Ordering::Relaxed)
            + dispatched,
        "global invariant broken"
    );
}

#[test]
fn hot_saturation_sheds_only_the_hot_tenant_reject_newest() {
    let (data, registry) = two_tenant_registry(31);
    let expected = registry.get("cold").unwrap().predict(&data.x).unwrap();
    let (engine, gate) = gated_engine(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 4,
            max_wait: Duration::ZERO,
            workers: 1,
            max_queue: 4,
            shed_policy: ShedPolicy::RejectNewest,
            ..ServeConfig::default()
        },
    );
    let rows: Vec<Vec<(u32, f32)>> = (0..8).map(|i| data.x.row_entries(i)).collect();

    // First hot batch dispatches and blocks on the gate; the hot queue
    // then fills to its 4-slot cap behind it.
    let first = engine.submit("hot", &rows[0]);
    wait_for_batches(engine.metrics(), 1);
    let mut hot_queued = Vec::new();
    for r in &rows[1..5] {
        hot_queued.push(engine.submit("hot", r));
    }
    // Hot is saturated: further hot submits shed...
    let err = engine.try_submit("hot", &rows[5]).unwrap_err();
    assert_eq!(err, ServeError::QueueFull { max_queue: 4 });
    // ...while the cold tenant's sub-queue admits its full cap untouched.
    let cold_queued: Vec<_> = (0..4).map(|i| engine.submit("cold", &rows[i])).collect();
    assert!(cold_queued.iter().all(|t| t.try_get().is_none()), "cold admitted");

    let hot_m = engine.metrics().model("hot");
    let cold_m = engine.metrics().model("cold");
    assert_eq!(hot_m.rejected_full.load(Ordering::Relaxed), 1);
    assert_eq!(cold_m.shed(), 0, "cold tenant must not shed while hot saturates");
    // Mid-flight: invariants hold per model and globally under shedding
    // (one hot request is dispatched and blocked on the gate).
    assert_bucket_invariant(&hot_m, "hot", 1);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert_global_invariant(engine.metrics(), 1);

    // Drain: every admitted request of both tenants completes correctly.
    open_gate(&gate);
    assert_eq!(first.wait().unwrap().label, expected[0]);
    for (i, t) in hot_queued.iter().enumerate() {
        assert_eq!(t.wait().unwrap().label, expected[i + 1]);
    }
    for (i, t) in cold_queued.iter().enumerate() {
        assert_eq!(t.wait().unwrap().label, expected[i]);
    }
    assert_eq!(cold_m.completed.load(Ordering::Relaxed), 4);
    assert_eq!(cold_m.failed.load(Ordering::Relaxed), 0);
    assert_bucket_invariant(&hot_m, "hot", 0);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert_global_invariant(engine.metrics(), 0);
    engine.shutdown();
}

#[test]
fn deadline_shedding_stays_within_the_hot_tenant() {
    let (data, registry) = two_tenant_registry(32);
    let (engine, gate) = gated_engine(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 1,
            // Zero latency budget: every queued request is instantly past
            // its deadline, so a full-queue submit sheds the whole
            // overdue prefix of *that model's* queue.
            max_wait: Duration::ZERO,
            workers: 1,
            max_queue: 4,
            shed_policy: ShedPolicy::DropExpired,
            ..ServeConfig::default()
        },
    );
    let rows: Vec<Vec<(u32, f32)>> = (0..8).map(|i| data.x.row_entries(i)).collect();

    let _first = engine.submit("hot", &rows[0]);
    wait_for_batches(engine.metrics(), 1);
    // Two cold requests sit queued below their cap — never shed.
    let cold_queued: Vec<_> = (0..2).map(|i| engine.submit("cold", &rows[i])).collect();
    // Fill hot to its cap, let the zero deadline lapse, then overflow it:
    // the overdue hot prefix is dropped, the newcomer admitted.
    let mut hot_victims = Vec::new();
    for r in &rows[1..5] {
        hot_victims.push(engine.submit("hot", r));
    }
    std::thread::sleep(Duration::from_millis(5));
    let hot_fresh = engine.submit("hot", &rows[5]);
    for v in &hot_victims {
        let err = v.try_get().expect("shed synchronously").unwrap_err();
        assert!(matches!(err, ServeError::DeadlineExceeded { .. }), "got: {err}");
    }
    assert!(hot_fresh.try_get().is_none(), "newcomer admitted into freed space");
    assert!(
        cold_queued.iter().all(|t| t.try_get().is_none()),
        "cold requests must survive hot-tenant deadline shedding"
    );

    let hot_m = engine.metrics().model("hot");
    let cold_m = engine.metrics().model("cold");
    assert_eq!(hot_m.shed_expired.load(Ordering::Relaxed), 4);
    assert!(hot_m.queue_depth_max.load(Ordering::Relaxed) <= 4, "cap never overshot");
    assert_eq!(cold_m.shed(), 0);
    // One hot request (the first batch) is dispatched and gate-blocked.
    assert_bucket_invariant(&hot_m, "hot", 1);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert_global_invariant(engine.metrics(), 1);

    open_gate(&gate);
    for t in &cold_queued {
        assert!(t.wait().is_ok(), "cold request completes");
    }
    assert!(hot_fresh.wait().is_ok());
    // hot_fresh resolving implies the earlier dispatched hot request
    // resolved too (single worker, per-model FIFO): quiescent now.
    assert_bucket_invariant(&hot_m, "hot", 0);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert_global_invariant(engine.metrics(), 0);
    engine.shutdown();
}

#[test]
fn remove_model_fails_its_queue_and_leaves_other_tenants_alone() {
    let (data, registry) = two_tenant_registry(33);
    let (engine, gate) = gated_engine(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 4,
            max_wait: Duration::ZERO,
            workers: 1,
            max_queue: 0,
            shed_policy: ShedPolicy::RejectNewest,
            ..ServeConfig::default()
        },
    );
    let rows: Vec<Vec<(u32, f32)>> = (0..6).map(|i| data.x.row_entries(i)).collect();

    let hot_first = engine.submit("hot", &rows[0]);
    wait_for_batches(engine.metrics(), 1);
    let hot_queued = engine.submit("hot", &rows[1]);
    let cold_queued: Vec<_> = (0..2).map(|i| engine.submit("cold", &rows[i])).collect();

    // Remove the cold tenant: its queued requests fail with a clear
    // error, its bucket's invariant closes, and the registry forgets it.
    let removed = engine.remove_model("cold");
    assert!(removed.is_some());
    assert!(engine.registry().get("cold").is_none());
    for t in &cold_queued {
        let err = t.try_get().expect("failed at removal").unwrap_err();
        assert!(err.to_string().contains("removed"), "got: {err}");
        assert!(!err.is_shed(), "removal is not load shedding");
    }
    let cold_m = engine.metrics().model("cold");
    assert_eq!(cold_m.failed.load(Ordering::Relaxed), 2);
    assert_eq!(cold_m.queue_depth.load(Ordering::Relaxed), 0);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert!(engine.remove_model("cold").is_none(), "idempotent");

    // The hot tenant is untouched: queued and in-flight work completes.
    open_gate(&gate);
    assert!(hot_first.wait().is_ok());
    assert!(hot_queued.wait().is_ok());
    assert_global_invariant(engine.metrics(), 0);
    engine.shutdown();
}

#[test]
fn shed_without_room_still_resolves_tickets_once() {
    // Lowering a live cap can leave a queue over its bound with a mix of
    // expired and fresh requests: the overflow submit then sheds the
    // expired prefix AND rejects the newcomer. The shed tickets must
    // resolve as `DeadlineExceeded` exactly once — dropped unfulfilled
    // they would resolve as `Abandoned` and double-count `failed`.
    let (data, registry) = two_tenant_registry(36);
    let (engine, gate) = gated_engine(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(100),
            workers: 1,
            max_queue: 6,
            shed_policy: ShedPolicy::DropExpired,
            ..ServeConfig::default()
        },
    );
    let rows: Vec<Vec<(u32, f32)>> = (0..8).map(|i| data.x.row_entries(i)).collect();

    let first = engine.submit("hot", &rows[0]);
    wait_for_batches(engine.metrics(), 1);
    // Two requests that will be overdue by overflow time...
    let stale: Vec<_> = (0..2).map(|i| engine.submit("hot", &rows[i])).collect();
    std::thread::sleep(Duration::from_millis(150));
    // ...then four fresh ones, filling the queue to the original cap.
    let mut fresh = Vec::new();
    for r in &rows[1..5] {
        fresh.push(engine.submit("hot", r));
    }
    // Lower the live cap below the fresh backlog, then overflow: the
    // stale prefix sheds, yet the queue is still over the new cap, so
    // the newcomer is rejected too.
    engine
        .update_model_config("hot", |c| c.max_queue = Some(3))
        .unwrap();
    let err = engine.try_submit("hot", &rows[5]).unwrap_err();
    assert_eq!(err, ServeError::QueueFull { max_queue: 3 });
    for t in &stale {
        let got = t.try_get().expect("resolved synchronously").unwrap_err();
        assert!(matches!(got, ServeError::DeadlineExceeded { .. }), "got: {got}");
    }
    let hot_m = engine.metrics().model("hot");
    assert_eq!(hot_m.shed_expired.load(Ordering::Relaxed), 2);
    // failed = 2 shed + 1 rejected newcomer, each counted exactly once.
    assert_eq!(hot_m.failed.load(Ordering::Relaxed), 3);
    assert_bucket_invariant(&hot_m, "hot", 1);
    assert_global_invariant(engine.metrics(), 1);

    open_gate(&gate);
    assert!(first.wait().is_ok());
    for t in &fresh {
        assert!(t.wait().is_ok());
    }
    assert_bucket_invariant(&hot_m, "hot", 0);
    assert_global_invariant(engine.metrics(), 0);
    engine.shutdown();
}

#[test]
fn set_model_config_applies_live_and_rejects_unregistered_names() {
    let (_data, registry) = two_tenant_registry(34);
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..ServeConfig::default()
        },
    );
    engine
        .set_model_config(
            "hot",
            ModelServeConfig {
                weight: 5,
                max_queue: Some(16),
            },
        )
        .unwrap();
    // Stored in the registry (survives hot swaps)...
    assert_eq!(registry.serve_config("hot").weight, 5);
    assert_eq!(registry.serve_config("hot").max_queue, Some(16));
    // ...and visible in the metrics bucket for /metrics consumers.
    assert_eq!(engine.metrics().model("hot").weight(), 5);
    // Unregistered names are refused (no unbounded config/metrics maps).
    assert!(engine
        .set_model_config("ghost", ModelServeConfig::default())
        .is_err());
    engine.shutdown();
}

#[test]
fn weighted_tenants_complete_under_contention() {
    // End-to-end smoke over the DRR path with live workers: two tenants,
    // asymmetric weights, interleaved submission — every request
    // completes with the right prediction and both buckets close their
    // invariants. (Exact dispatch order is pinned by the scheduler's
    // unit tests; this exercises the full engine under real threading.)
    let (data, registry) = two_tenant_registry(35);
    registry.set_serve_config(
        "hot",
        ModelServeConfig {
            weight: 3,
            max_queue: None,
        },
    );
    let expected = registry.get("hot").unwrap().predict(&data.x).unwrap();
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ..ServeConfig::default()
        },
    );
    let rows: Vec<Vec<(u32, f32)>> = (0..data.len()).map(|i| data.x.row_entries(i)).collect();
    let tickets: Vec<(usize, _)> = rows
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let name = if i % 3 == 0 { "cold" } else { "hot" };
            (i, engine.submit(name, r))
        })
        .collect();
    for (i, t) in &tickets {
        assert_eq!(t.wait().unwrap().label, expected[*i]);
    }
    let hot_m = engine.metrics().model("hot");
    let cold_m = engine.metrics().model("cold");
    assert_eq!(hot_m.failed.load(Ordering::Relaxed), 0);
    assert_eq!(cold_m.failed.load(Ordering::Relaxed), 0);
    assert_eq!(
        hot_m.completed.load(Ordering::Relaxed) + cold_m.completed.load(Ordering::Relaxed),
        data.len() as u64
    );
    assert_bucket_invariant(&hot_m, "hot", 0);
    assert_bucket_invariant(&cold_m, "cold", 0);
    assert_global_invariant(engine.metrics(), 0);
    engine.shutdown();
}
