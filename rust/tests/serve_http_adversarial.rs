//! Adversarial protocol battery for the HTTP front-end.
//!
//! Every scenario throws malformed, hostile, or pathological traffic at
//! the server and asserts three things: the response (if any) maps to
//! the documented 4xx/close, the process never panics, and the engine's
//! accounting invariant (`submitted == completed + failed + queued`)
//! survives. Scenarios run under **both** io models; the slow-loris
//! drill is evented-only because only the event loop owns a deadline
//! reaper (`--idle-timeout-ms`).
//!
//! No scenario needs a trained model: predict POSTs target an
//! unregistered name, which still exercises submit/fail accounting.

use lpdsvm::serve::http::{MAX_BODY, MAX_HEADERS, MAX_HEADER_LINE};

/// [`MAX_HEADER_LINE`] as a length (the crate constant is `u64` because
/// it feeds `Read::take`).
const LINE_CAP: usize = MAX_HEADER_LINE as usize;
use lpdsvm::serve::{HttpOptions, HttpServer, IoModel, ModelRegistry, ServeConfig, ServeEngine};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn engine() -> Arc<ServeEngine> {
    Arc::new(ServeEngine::start(
        Arc::new(ModelRegistry::new()),
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ..ServeConfig::default()
        },
    ))
}

fn serve_opts(
    io: IoModel,
    max_connections: usize,
    idle_timeout: Duration,
) -> (Arc<ServeEngine>, HttpServer) {
    let engine = engine();
    let server = HttpServer::bind_with_opts(
        Arc::clone(&engine),
        "127.0.0.1:0",
        HttpOptions {
            io_model: io,
            max_connections,
            idle_timeout,
        },
    )
    .unwrap();
    (engine, server)
}

fn serve(io: IoModel) -> (Arc<ServeEngine>, HttpServer) {
    let cap = HttpOptions::default().max_connections;
    serve_opts(io, cap, HttpOptions::default().idle_timeout)
}

/// Read one length-framed response off a (possibly keep-alive) stream.
fn read_response<R: BufRead>(reader: &mut R) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .unwrap();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// Write raw request bytes on a fresh connection and read one response.
fn send_raw(addr: SocketAddr, request: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(request).unwrap();
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

fn healthz(addr: SocketAddr) -> (u16, String) {
    send_raw(addr, b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")
}

/// The load-bearing invariant: after the engine quiesces, every
/// submitted request is accounted for — completed, failed, or still
/// queued — and nothing ever panicked inside batch scoring.
fn assert_engine_sane(engine: &ServeEngine) {
    let m = engine.metrics();
    let t0 = Instant::now();
    loop {
        let submitted = m.submitted.load(Ordering::SeqCst);
        let accounted = m.completed.load(Ordering::SeqCst)
            + m.failed.load(Ordering::SeqCst)
            + m.queue_depth.load(Ordering::SeqCst);
        if submitted == accounted {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "metrics invariant violated: submitted={submitted} accounted={accounted}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(m.batch_panics.load(Ordering::SeqCst), 0, "a batch panicked");
}

// ---------------------------------------------------------------------------
// Fragmented delivery
// ---------------------------------------------------------------------------

fn drip_fed_request_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    // One byte per write: every head-scan resume path gets exercised.
    for byte in b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n" {
        stream.write_all(&[*byte]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let (status, body) = read_response(&mut BufReader::new(stream));
    assert_eq!(status, 200, "body: {body}");
    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn drip_fed_request_is_served() {
    drip_fed_request_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn drip_fed_request_is_served_evented() {
    drip_fed_request_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Line and header caps, at the boundary and one past it
// ---------------------------------------------------------------------------

fn line_cap_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();

    // A request line of exactly MAX_HEADER_LINE bytes (CRLF included)
    // parses; the padded path just routes to 404.
    let prefix = "GET /nope?";
    let suffix = " HTTP/1.1\r\n";
    let pad = "a".repeat(LINE_CAP - prefix.len() - suffix.len());
    let req = format!("{prefix}{pad}{suffix}connection: close\r\n\r\n");
    let (status, body) = send_raw(addr, req.as_bytes());
    assert_eq!(status, 404, "at-cap request line must parse; body: {body}");

    // A newline-free flood hits the cap and is rejected without ever
    // finding a request. Exactly LINE_CAP bytes: the server consumes
    // everything sent before erroring, so the close is clean (no unread
    // bytes, no reset racing the 400).
    let flood = vec![b'a'; LINE_CAP];
    let (status, body) = send_raw(addr, &flood);
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("byte limit"), "body: {body}");

    // Same cap applies to header lines (again sized for exact
    // consumption: request line + one newline-free LINE_CAP header).
    let mut req = b"GET /healthz HTTP/1.1\r\nx-junk: ".to_vec();
    req.extend(vec![b'a'; LINE_CAP - "x-junk: ".len()]);
    let (status, body) = send_raw(addr, &req);
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("byte limit"), "body: {body}");

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn line_cap_enforced_at_boundary() {
    line_cap_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn line_cap_enforced_at_boundary_evented() {
    line_cap_scenario(IoModel::Evented);
}

fn header_count_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();

    // MAX_HEADERS - 1 headers (the last one is connection: close) parse.
    let mut ok = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..MAX_HEADERS - 2 {
        ok.push_str(&format!("x-h{i}: v\r\n"));
    }
    ok.push_str("connection: close\r\n\r\n");
    let (status, body) = send_raw(addr, ok.as_bytes());
    assert_eq!(status, 200, "body: {body}");

    // One more header tips over the cap.
    let mut over = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..MAX_HEADERS - 1 {
        over.push_str(&format!("x-h{i}: v\r\n"));
    }
    over.push_str("connection: close\r\n\r\n");
    let (status, body) = send_raw(addr, over.as_bytes());
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("header lines"), "body: {body}");

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn header_count_enforced_at_boundary() {
    header_count_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn header_count_enforced_at_boundary_evented() {
    header_count_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Body cap: 413 before the body is read; exactly-at-cap is accepted
// ---------------------------------------------------------------------------

fn body_cap_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();

    // Declaring one byte over the cap draws the 413 immediately — the
    // client never has to (and here never does) send the body.
    let req = format!(
        "POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        MAX_BODY + 1
    );
    let (status, body) = send_raw(addr, req.as_bytes());
    assert_eq!(status, 413, "body: {body}");
    assert!(body.contains("exceeds"), "body: {body}");

    // Exactly at the cap the body is read in full; the payload is
    // garbage JSON, so the predict route answers 400 — but the framing
    // layer accepted it.
    let payload = vec![b'x'; MAX_BODY];
    let mut req = format!(
        "POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        payload.len()
    )
    .into_bytes();
    req.extend_from_slice(&payload);
    let (status, body) = send_raw(addr, &req);
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("invalid JSON"), "body: {body}");

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn body_cap_413_at_cap_plus_one_accepts_at_cap() {
    body_cap_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn body_cap_413_at_cap_plus_one_accepts_at_cap_evented() {
    body_cap_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Framing abuse: chunked encoding, binary garbage, pipelining
// ---------------------------------------------------------------------------

fn bad_framing_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();

    let (status, body) = send_raw(
        addr,
        b"POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n",
    );
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("transfer-encoding"), "body: {body}");

    let (status, _body) = send_raw(addr, b"\xff\xfe\xfd\xfc garbage\r\n\r\n");
    assert_eq!(status, 400, "binary garbage must map to 400, not a hang");

    let (status, body) = send_raw(
        addr,
        b"GET /healthz HTTP/1.1\r\ncontent-length: banana\r\nconnection: close\r\n\r\n",
    );
    assert_eq!(status, 400, "body: {body}");
    assert!(body.contains("bad content-length"), "body: {body}");

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn bad_framing_maps_to_400() {
    bad_framing_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn bad_framing_maps_to_400_evented() {
    bad_framing_scenario(IoModel::Evented);
}

fn pipelined_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    // Three requests in one write; the final one asks to close.
    stream
        .write_all(
            b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n\
              GET /v1/models HTTP/1.1\r\nhost: t\r\n\r\n\
              GET /nope HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n",
        )
        .unwrap();
    let mut reader = BufReader::new(stream);
    let (s1, b1) = read_response(&mut reader);
    let (s2, b2) = read_response(&mut reader);
    let (s3, _) = read_response(&mut reader);
    assert_eq!((s1, s2, s3), (200, 200, 404));
    assert!(b1.contains("status"), "healthz first: {b1}");
    assert!(b2.contains("models"), "listing second: {b2}");
    // The close directive on the last request was honoured.
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn pipelined_requests_answered_in_order() {
    pipelined_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn pipelined_requests_answered_in_order_evented() {
    pipelined_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Abrupt disconnects
// ---------------------------------------------------------------------------

fn abrupt_disconnect_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();

    // Mid-body: declare 4096 bytes, deliver 64, vanish.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            b"POST /v1/models/m:predict HTTP/1.1\r\nhost: t\r\ncontent-length: 4096\r\n\r\n",
        )
        .unwrap();
    stream.write_all(&[b'{'; 64]).unwrap();
    drop(stream);

    // Mid-headers: vanish after half a request line.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"GET /heal").unwrap();
    drop(stream);

    // Connect-and-vanish without a single byte.
    drop(TcpStream::connect(addr).unwrap());

    // The server shrugs all three off: still answering, accounts intact.
    let (status, body) = healthz(addr);
    assert_eq!(status, 200, "body: {body}");
    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn abrupt_disconnects_leave_server_healthy() {
    abrupt_disconnect_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn abrupt_disconnects_leave_server_healthy_evented() {
    abrupt_disconnect_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Slow-loris: tricklers are reaped by the idle deadline, bystanders
// keep their latency (evented only — the deadline reaper lives in the
// event loop; the threaded model bounds the same abuse with its socket
// read timeout but does not count reaps)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
#[test]
fn slow_loris_tricklers_are_reaped_and_counted() {
    const TRICKLERS: usize = 6;
    let (engine, server) = serve_opts(
        IoModel::Evented,
        HttpOptions::default().max_connections,
        Duration::from_millis(400),
    );
    let addr = server.addr();

    // Each trickler leaks one header byte per 100 ms — a full request
    // would take ~4 s against a 400 ms deadline.
    let handles: Vec<_> = (0..TRICKLERS)
        .map(|_| {
            std::thread::spawn(move || {
                let mut stream = match TcpStream::connect(addr) {
                    Ok(s) => s,
                    Err(_) => return,
                };
                for byte in b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n" {
                    if stream.write_all(&[*byte]).is_err() {
                        return; // reaped: the server closed on us
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            })
        })
        .collect();

    // A well-behaved bystander is not head-of-line blocked by the
    // tricklers: p99 for a healthz round-trip stays interactive.
    std::thread::sleep(Duration::from_millis(150));
    let mut worst = Duration::ZERO;
    for _ in 0..5 {
        let t0 = Instant::now();
        let (status, _) = healthz(addr);
        worst = worst.max(t0.elapsed());
        assert_eq!(status, 200);
    }
    assert!(
        worst < Duration::from_secs(2),
        "bystander latency degraded to {worst:?} under slow-loris"
    );

    // Every trickler is reaped by the deadline and counted.
    let t0 = Instant::now();
    loop {
        let reaped = engine.metrics().conn_idle_reaped.load(Ordering::SeqCst);
        if reaped >= TRICKLERS as u64 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "only {reaped}/{TRICKLERS} tricklers reaped"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

// ---------------------------------------------------------------------------
// Connection churn: the open-connection gauge returns to baseline no
// matter how clients leave
// ---------------------------------------------------------------------------

fn churn_scenario(io: IoModel) {
    let (engine, server) = serve(io);
    let addr = server.addr();
    let baseline = engine.metrics().conn_open.load(Ordering::SeqCst);

    for round in 0..40 {
        match round % 3 {
            // Clean keep-alive client: two requests, then EOF from us.
            0 => {
                let stream = TcpStream::connect(addr).unwrap();
                stream
                    .set_read_timeout(Some(Duration::from_secs(30)))
                    .unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                writer
                    .write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n")
                    .unwrap();
                let (status, _) = read_response(&mut reader);
                assert_eq!(status, 200, "round {round}");
                writer
                    .write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")
                    .unwrap();
                let (status, _) = read_response(&mut reader);
                assert_eq!(status, 200, "round {round}");
            }
            // Abrupt closer with a half-written request.
            1 => {
                let mut stream = TcpStream::connect(addr).unwrap();
                let _ = stream.write_all(b"POST /v1/mod");
                drop(stream);
            }
            // Connect-and-vanish.
            _ => {
                drop(TcpStream::connect(addr).unwrap());
            }
        }
    }

    // Every connection path — clean close, abrupt close, silent vanish —
    // must decrement what accept incremented.
    let t0 = Instant::now();
    loop {
        let open = engine.metrics().conn_open.load(Ordering::SeqCst);
        if open == baseline {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "conn_open stuck at {open}, baseline {baseline}: leaked connection accounting"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let (status, _) = healthz(addr);
    assert_eq!(status, 200);
    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn connection_churn_returns_gauge_to_baseline() {
    churn_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn connection_churn_returns_gauge_to_baseline_evented() {
    churn_scenario(IoModel::Evented);
}

// ---------------------------------------------------------------------------
// Over-cap 503 delivery must not depend on earlier victims reading
// theirs (regression: the accept path once wrote the 503 blocking,
// so one unread rejection could stall every later accept)
// ---------------------------------------------------------------------------

fn over_cap_scenario(io: IoModel) {
    let (engine, server) = serve_opts(io, 1, HttpOptions::default().idle_timeout);
    let addr = server.addr();

    // Occupy the single slot.
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer
        .write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: keep-alive\r\n\r\n")
        .unwrap();
    let (status, _) = read_response(&mut reader);
    assert_eq!(status, 200);

    // Victim A connects over the cap and never reads its 503.
    let victim_a = TcpStream::connect(addr).unwrap();

    // Victim B must still get its 503 promptly — A's unread rejection
    // cannot be allowed to stall the accept path.
    let t0 = Instant::now();
    let probe = TcpStream::connect(addr).unwrap();
    probe
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut probe_reader = BufReader::new(probe);
    let (status, body) = read_response(&mut probe_reader);
    assert_eq!(status, 503, "body: {body}");
    assert!(body.contains("connection limit"), "body: {body}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "503 delivery stalled {:?} behind an unread rejection",
        t0.elapsed()
    );

    // Release everything; the server recovers.
    drop(victim_a);
    drop(reader);
    drop(writer);
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr).and_then(|mut s| {
            s.set_read_timeout(Some(Duration::from_secs(5)))?;
            s.write_all(b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")?;
            let mut line = String::new();
            BufReader::new(s).read_line(&mut line)?;
            Ok(line.contains(" 200 "))
        }) {
            Ok(true) => break,
            _ => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "connection slot never freed"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }

    assert_engine_sane(&engine);
    server.shutdown();
    engine.shutdown();
}

#[test]
fn over_cap_503_not_stalled_by_unread_rejections() {
    over_cap_scenario(IoModel::Threads);
}

#[cfg(target_os = "linux")]
#[test]
fn over_cap_503_not_stalled_by_unread_rejections_evented() {
    over_cap_scenario(IoModel::Evented);
}
