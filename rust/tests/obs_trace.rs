//! End-to-end tracing test: span recording is process-global state, so
//! the scenarios that arm/drain it live in this separate test binary
//! where they own the process (library unit tests never enable spans).

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::obs::export::{chrome_trace, phase_table, write_chrome_trace};
use lpdsvm::obs::span;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::json::Json;

fn find<'a>(events: &'a [Json], name: &str) -> Option<&'a Json> {
    events.iter().find(|e| {
        e.get("ph").and_then(|p| p.as_str()) == Some("X")
            && e.get("name").and_then(|n| n.as_str()) == Some(name)
    })
}

#[test]
fn traced_train_exports_a_parseable_chrome_trace() {
    // Tracing is process-global, so this binary holds exactly one test:
    // a second `#[test]` toggling enable/disable would race this one.
    // Before arming: spans are disarmed at construction and args no-op.
    let mut disarmed = lpdsvm::obs::Span::new("never");
    disarmed.arg("x", 1.0);
    assert!(!disarmed.armed());
    drop(disarmed);

    let spec = PaperDataset::Adult.spec(0.01, 5);
    let data = spec.synth.generate();
    let cfg = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: 32,
            ..Default::default()
        },
        solver: SolverOptions {
            c: spec.c,
            ..Default::default()
        },
        threads: 2,
        ..Default::default()
    };

    span::enable();
    let model = train(&data, &cfg).unwrap();
    span::disable();
    assert!(model.factor.rank > 0);

    let dumps = span::drain();
    assert!(!dumps.is_empty(), "no thread recorded any span");

    // Round-trip through the exporter and our own JSON parser — exactly
    // what `--trace` writes and Perfetto loads.
    let doc = chrome_trace(&dumps);
    let back = Json::parse(&doc.to_string()).unwrap();
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();

    // One thread_name metadata event per contributing thread.
    let meta_count = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
        .count();
    let n_threads = dumps.iter().filter(|d| !d.records.is_empty()).count();
    assert_eq!(meta_count, n_threads);

    // Every X event is complete: name, tid, ts, dur.
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) != Some("X") {
            continue;
        }
        assert!(e.get("name").and_then(|n| n.as_str()).is_some(), "unnamed X event");
        assert!(e.get("tid").and_then(|t| t.as_u64()).is_some());
        assert!(e.get("ts").and_then(|t| t.as_u64()).is_some());
        assert!(e.get("dur").and_then(|d| d.as_u64()).is_some());
    }

    // The span taxonomy the CLI promises: root, stage-1 phases, the
    // eigensolver, and per-epoch solver spans must all be present.
    let train_ev = find(events, "train").expect("missing 'train' span");
    for name in ["stage.preparation", "stage.matrix_g", "eigensolve", "solve", "solve.epoch"] {
        assert!(find(events, name).is_some(), "missing '{name}' span");
    }

    // Hierarchy is timestamp containment: the stage-1 phases sit inside
    // the root train span on the same thread.
    let t0 = train_ev.get("ts").unwrap().as_u64().unwrap();
    let t1 = t0 + train_ev.get("dur").unwrap().as_u64().unwrap();
    let train_tid = train_ev.get("tid").unwrap().as_u64().unwrap();
    for name in ["stage.preparation", "stage.matrix_g"] {
        let e = find(events, name).unwrap();
        assert_eq!(e.get("tid").unwrap().as_u64().unwrap(), train_tid);
        let s0 = e.get("ts").unwrap().as_u64().unwrap();
        let s1 = s0 + e.get("dur").unwrap().as_u64().unwrap();
        assert!(t0 <= s0 && s1 <= t1, "'{name}' [{s0},{s1}] outside train [{t0},{t1}]");
    }

    // Solver epochs carry the structured convergence fields.
    let epoch = find(events, "solve.epoch").unwrap();
    let args = epoch.get("args").unwrap();
    for key in ["epoch", "kkt", "active", "shrunk"] {
        assert!(args.get(key).and_then(|v| v.as_f64()).is_some(), "epoch missing arg '{key}'");
    }
    let solve = find(events, "solve").unwrap().get("args").unwrap();
    assert!(solve.get("epochs").and_then(|v| v.as_f64()).unwrap_or(0.0) >= 1.0);

    // The same dumps drive the CLI's summary table.
    let summary = phase_table(&dumps).render();
    assert!(summary.contains("solve.epoch"), "{summary}");

    // And the file writer drops valid JSON where --trace points.
    let path = std::env::temp_dir().join("lpdsvm_obs_trace_test/trace.json");
    write_chrome_trace(&path, &dumps).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(Json::parse(&text).is_ok());
    std::fs::remove_file(&path).ok();

    // Drain is destructive: the buffers reset for the next run.
    let total: usize = span::drain().iter().map(|d| d.records.len()).sum();
    assert_eq!(total, 0, "drain did not reset the ring buffers");
}
