//! Paper Table 3: hyperparameter grid search + cross-validation timings.
//!
//! Grid: log2(C) ∈ {0..9} (10 values) × log2(γ) ∈ {γ*−2 .. γ*+2} (5
//! values), 5-fold CV ⇒ 250·C(c,2) binary problems per dataset. Reports
//! total time, time per binary problem, and the speed-up relative to
//! training the same problem in isolation (single-run time ÷ per-problem
//! time), exactly as the paper's table 3 does.

mod harness;

use lpdsvm::coordinator::grid::{grid_search, GridConfig};
use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::report::Table;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::rng::Rng;

fn main() {
    let scale = harness::bench_scale();
    let seed = harness::bench_seed();
    println!("table3_gridsearch: scale={scale} seed={seed}\n");

    let datasets = [
        PaperDataset::Adult,
        PaperDataset::Epsilon,
        PaperDataset::Susy,
        PaperDataset::Mnist8m,
    ];

    let mut t = Table::new(
        "Table 3 analogue: grid search + 5-fold CV",
        &[
            "dataset",
            "total s",
            "problems",
            "s/problem",
            "single-run s",
            "speed-up",
            "best (C, gamma)",
        ],
    );

    for ds in datasets {
        let spec = ds.spec(ds.scale_with_floor(scale, 2_000), seed);
        let data = spec.synth.generate();
        let mut rng = Rng::new(seed ^ 0x717);
        let (train_set, _) = data.split(0.2, &mut rng);

        let base = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: spec.budget,
                seed,
                ..Default::default()
            },
            solver: SolverOptions {
                seed,
                ..Default::default()
            },
            ..Default::default()
        };

        // Paper grid: C = 2^0..2^9; gamma = gamma* × 4^{-2..2}.
        let grid = GridConfig {
            c_values: (0..10).map(|i| 2f64.powi(i)).collect(),
            gamma_values: (-2..=2).map(|i| spec.gamma * 4f64.powi(i)).collect(),
            cv_folds: 5,
            seed,
            warm_start: true,
        };
        let result = grid_search(&train_set, &base, &grid).expect("grid search");

        // Single isolated training run at the tuned parameters, for the
        // speed-up denominator (the paper divides table-2 training time by
        // the per-problem time).
        let mut single_cfg = base.clone();
        single_cfg.kernel = Kernel::gaussian(spec.gamma);
        single_cfg.solver.c = spec.c;
        let (_, single_s) = harness::time_once(|| train(&train_set, &single_cfg).unwrap());

        let per_problem = result.secs_per_problem();
        // Paper's speed-up definition: table-2 training time *per binary
        // problem* (single run ÷ its OVO pair count) divided by the grid's
        // per-problem time.
        let n_pairs = (data.n_classes * (data.n_classes - 1) / 2).max(1);
        let speedup = single_s / n_pairs as f64 / per_problem.max(1e-12);
        t.row(&[
            ds.name().into(),
            Table::secs(result.total_secs),
            result.n_binary_problems.to_string(),
            format!("{:.4}", per_problem),
            Table::secs(single_s),
            format!("x{speedup:.2}"),
            format!("({}, {:.2e})", result.best_c, result.best_gamma),
        ]);
        println!(
            "{}: grid done — {} problems in {:.1}s (stage1 {:.1}s, best err {:.2}%)",
            ds.name(),
            result.n_binary_problems,
            result.total_secs,
            result.stage1_secs,
            result.best_error * 100.0
        );
    }
    println!();
    t.print();
    let path = harness::report_dir().join("table3.tsv");
    t.write_tsv(&path).unwrap();
    println!("table 3 written to {}", path.display());
}
