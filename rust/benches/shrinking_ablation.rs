//! Paper §5 "Shrinking": stage-2 (SMO) training time with shrinking ON vs
//! OFF, restricted — as the paper does — to the second phase only.
//!
//! Paper numbers: ×220 on Adult, ×350 on Epsilon. The factor grows with
//! problem size (late-phase epochs over a huge mostly-converged variable
//! set), so at bench scale the expected shape is a factor ≫ 1 that grows
//! with n; we sweep n to show the trend.

mod harness;

use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{LowRankFactor, Stage1Config};
use lpdsvm::report::Table;
use lpdsvm::solver::{solve, ProblemView, SolverOptions};
use lpdsvm::util::timer::StageClock;

fn main() {
    let scale = harness::bench_scale();
    let seed = harness::bench_seed();
    println!("shrinking_ablation: scale={scale} seed={seed}\n");

    let mut t = Table::new(
        "Shrinking ablation (stage-2 time only, as in the paper)",
        &[
            "dataset", "n", "B", "with (s)", "without (s)", "factor",
            "steps with", "steps without",
        ],
    );

    // The paper measured Adult and Epsilon (and stopped there because the
    // no-shrinking runs became excessive — same reason we keep n modest).
    for (ds, mult) in [
        (PaperDataset::Adult, 1.0),
        (PaperDataset::Adult, 4.0),
        (PaperDataset::Epsilon, 1.0),
        (PaperDataset::Epsilon, 4.0),
    ] {
        let spec = ds.spec(ds.scale_with_floor(scale * mult, 2_000), seed);
        let data = spec.synth.generate();
        let kernel = Kernel::gaussian(spec.gamma);
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(
            &data.x,
            kernel,
            &Stage1Config {
                budget: spec.budget,
                seed,
                ..Default::default()
            },
            &NativeBackend::default(),
            &mut clock,
        )
        .expect("stage 1");
        let rows: Vec<usize> = (0..data.len()).collect();
        let y = data.signed_labels();
        let p = ProblemView::new(&factor.g, &rows, &y);

        // Tight eps emphasises the late phase, where shrinking pays.
        let base = SolverOptions {
            c: spec.c,
            eps: 1e-3,
            max_epochs: 10_000,
            seed,
            ..Default::default()
        };
        let (sol_with, t_with) = harness::time_once(|| solve(&p, &base));
        let (sol_without, t_without) = harness::time_once(|| {
            solve(
                &p,
                &SolverOptions {
                    shrinking: false,
                    ..base.clone()
                },
            )
        });
        assert!(
            (sol_with.objective - sol_without.objective).abs()
                < 1e-2 * (1.0 + sol_without.objective.abs()),
            "shrinking changed the optimum: {} vs {}",
            sol_with.objective,
            sol_without.objective
        );
        t.row(&[
            ds.name().into(),
            data.len().to_string(),
            factor.rank.to_string(),
            format!("{t_with:.3}"),
            format!("{t_without:.3}"),
            format!("x{:.1}", t_without / t_with.max(1e-9)),
            sol_with.steps.to_string(),
            sol_without.steps.to_string(),
        ]);
        println!(
            "{} n={}: with={:.3}s without={:.3}s (objectives agree at {:.4})",
            ds.name(),
            data.len(),
            t_with,
            t_without,
            sol_with.objective
        );
    }
    println!();
    t.print();
    let path = harness::report_dir().join("shrinking.tsv");
    t.write_tsv(&path).unwrap();
    println!("written to {}", path.display());
}
