//! Eigensolver sweep: serial cyclic Jacobi (`sym_eig`) vs the
//! pool-parallel tournament ordering (`sym_eig_threads`) on a Gaussian
//! landmark matrix `K_BB` — the "preparation" slice of the paper's Fig. 3
//! breakdown, which dominates stage 1 at large landmark budgets B.
//!
//! Reports seconds per solve and the speedup of every thread count over
//! the serial path, checks the parallel spectrum against the serial one
//! (max |Δλ| must stay below 1e-6·λ_max) and that each thread count is
//! deterministic, then writes `BENCH_eigen.json` (override with
//! `LPDSVM_BENCH_EIGEN_OUT`) so the perf trajectory is tracked in-repo.
//!
//!     cargo bench --bench eigen_sweep              # full workload
//!     cargo bench --bench eigen_sweep -- --smoke   # CI fast mode

mod harness;

use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::linalg::eigen::{sym_eig, sym_eig_threads};
use lpdsvm::lowrank::landmarks;
use lpdsvm::report::Table;
use lpdsvm::util::json::{arr, num, obj, s, Json};
use lpdsvm::util::threads;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seed = harness::bench_seed();
    let cores = threads::default_threads();

    // A realistic K_BB: Gaussian kernel over dense synthetic landmarks.
    // B is the whole workload (Jacobi is O(B³) per sweep).
    let (b, p) = if smoke { (160usize, 32usize) } else { (640, 64) };
    let data = SynthSpec {
        name: "eigen-bench".into(),
        n: b,
        p,
        n_classes: 4,
        sep: 3.0,
        latent: 8,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate();
    let idx: Vec<usize> = (0..b).collect();
    let (lm, lm_sq) = landmarks::densify(&data.x, &idx);
    let kernel = Kernel::gaussian(0.5 / p as f64);
    let k_bb = kernel.symmetric_matrix_threads(&lm, &lm_sq, cores);
    println!(
        "eigen_sweep{}: B={b} p={p} cores={cores}\n",
        if smoke { " (smoke)" } else { "" }
    );

    let (serial, serial_secs) = harness::time_once(|| sym_eig(&k_bb, 40, 1e-12));
    let lmax = serial.values.first().copied().unwrap_or(0.0).max(1e-30);

    let mut sweep = vec![1usize, 2, 4, 8, cores];
    sweep.sort_unstable();
    sweep.dedup();

    let mut table = Table::new(
        "sym_eig sweep (serial cyclic vs pool tournament Jacobi)",
        &["solver", "threads", "secs", "speedup vs serial", "max |Δλ|/λmax"],
    );
    table.row(&[
        "sym_eig".into(),
        "1".into(),
        Table::secs(serial_secs),
        "1.00x".into(),
        "0".into(),
    ]);

    let mut rows_json: Vec<Json> = vec![obj(vec![
        ("solver", s("sym_eig")),
        ("threads", num(1.0)),
        ("secs", num(serial_secs)),
        ("speedup_vs_serial", num(1.0)),
    ])];
    let mut best_speedup = 1.0f64;
    let mut reference: Option<Vec<f64>> = None;

    for &t in &sweep {
        let (eig, secs) = harness::time_once(|| sym_eig_threads(&k_bb, 40, 1e-12, t));

        // Accuracy gate: the tournament ordering must land on the same
        // spectrum as the serial ordering (both converge to the same
        // off-diagonal bound).
        let max_dl = eig
            .values
            .iter()
            .zip(&serial.values)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_dl <= 1e-6 * lmax,
            "threads={t}: spectrum drifted by {max_dl} (λmax {lmax})"
        );
        // Determinism gate: every thread count must reproduce the same
        // decomposition (the phases are scheduling-independent).
        if reference.is_none() {
            reference = Some(eig.values.clone());
        }
        assert_eq!(
            reference.as_deref(),
            Some(eig.values.as_slice()),
            "threads={t} nondeterministic"
        );

        let speedup = serial_secs / secs.max(1e-12);
        best_speedup = best_speedup.max(speedup);
        table.row(&[
            "sym_eig_threads".into(),
            t.to_string(),
            Table::secs(secs),
            format!("{speedup:.2}x"),
            format!("{:.2e}", max_dl / lmax),
        ]);
        rows_json.push(obj(vec![
            ("solver", s("sym_eig_threads")),
            ("threads", num(t as f64)),
            ("secs", num(secs)),
            ("speedup_vs_serial", num(speedup)),
            ("max_abs_dlambda_rel", num(max_dl / lmax)),
        ]));
    }

    table.print();
    table.write_tsv(&harness::report_dir().join("eigen_sweep.tsv")).ok();
    println!("\nbest sym_eig speedup over serial: {best_speedup:.2}x on {cores} cores");

    let out_path = std::env::var("LPDSVM_BENCH_EIGEN_OUT")
        .unwrap_or_else(|_| "BENCH_eigen.json".to_string());
    let doc = obj(vec![
        ("bench", s("eigen_sweep")),
        ("source", s("cargo bench --bench eigen_sweep")),
        ("smoke", Json::Bool(smoke)),
        (
            "matrix",
            obj(vec![
                ("b", num(b as f64)),
                ("p", num(p as f64)),
                ("kernel", s(kernel.name())),
                ("seed", num(seed as f64)),
            ]),
        ),
        ("host_cores", num(cores as f64)),
        ("results", arr(rows_json)),
        ("best_speedup_vs_serial", num(best_speedup)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n").expect("write bench json");
    println!("wrote {out_path}");
}
