//! Out-of-core data plane: LIBSVM parse throughput and the cost of
//! blockwise training relative to the resident in-memory path.
//!
//! Three sections:
//!
//! 1. **Parse throughput** — the reused-buffer LIBSVM reader, reported in
//!    MB/s. This is the hot loop of the streaming path, which re-parses
//!    every shard once per epoch, so its throughput bounds how small a
//!    block budget can get before epochs become I/O-dominated.
//! 2. **Sharding** — `split` over the same file, plus a `ShardedSource`
//!    open (label pass + manifest check).
//! 3. **Blockwise vs in-memory training** — the same `train_streaming`
//!    entry point with budget 0 (one resident block, the reference), a
//!    stripe-sized budget over the in-memory source, and the same budget
//!    over the shard directory. All three models must be byte-identical —
//!    the bench doubles as a differential test — and the slowdown of the
//!    bounded-memory paths is what the JSON artifact tracks.
//!
//! Results land in `BENCH_oocore.json` (override with
//! `LPDSVM_BENCH_OOCORE_OUT`).
//!
//!     cargo bench --bench oocore              # full workload
//!     cargo bench --bench oocore -- --smoke   # CI fast mode

mod harness;

use lpdsvm::coordinator::train::{train_streaming, TrainConfig};
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::data::{libsvm, DataSource, MemorySource, ShardedSource};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::model::io as model_io;
use lpdsvm::model::multiclass::MulticlassModel;
use lpdsvm::report::Table;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::json::{num, obj, s, Json};
use lpdsvm::util::timer::StageClock;
use std::path::Path;

fn model_bytes(model: &MulticlassModel, dir: &Path, name: &str) -> Vec<u8> {
    let path = dir.join(name);
    model_io::save(model, &path).expect("serialize bench model");
    std::fs::read(&path).expect("read bench model back")
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seed = harness::bench_seed();
    let (n, p) = if smoke { (6_000, 24) } else { (60_000, 48) };

    // Dense features so the LIBSVM round-trip touches every column and
    // the text file has realistic per-row weight.
    let data = SynthSpec {
        name: "oocore-bench".into(),
        n,
        p,
        n_classes: 2,
        sep: 1.5,
        latent: 6,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate();

    let dir = std::env::temp_dir().join("lpdsvm_bench_oocore");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let svm = dir.join("data.svm");
    libsvm::write(&data, &svm).expect("write libsvm file");
    let bytes = std::fs::metadata(&svm).expect("stat libsvm file").len();
    let mb = bytes as f64 / (1024.0 * 1024.0);
    println!(
        "oocore{}: n={n} p={p} → {mb:.1} MB of LIBSVM text\n",
        if smoke { " (smoke)" } else { "" }
    );

    // --- 1. parse throughput ---
    let samples = if smoke { 3 } else { 7 };
    let stats = harness::bench_stats(1, samples, || {
        let ds = libsvm::read(&svm).expect("parse libsvm file");
        assert_eq!(ds.len(), n, "parse dropped rows");
    });
    harness::print_stats("libsvm parse (reused-buffer reader)", &stats, Some((mb, "MB")));
    let parse_mb_s_best = mb / stats.min.max(1e-12);

    // --- 2. shard + open ---
    let shard_dir = dir.join("shards");
    let parts = 8usize;
    let (_, split_secs) = harness::time_once(|| {
        libsvm::split_shards(&svm, &shard_dir, parts).expect("split shards")
    });
    let (sharded, open_secs) =
        harness::time_once(|| ShardedSource::open(&shard_dir).expect("open shard dir"));
    assert_eq!(sharded.n_rows(), n, "shard label pass lost rows");
    println!(
        "split into {parts} shards {} s, ShardedSource::open (label pass) {} s\n",
        Table::secs(split_secs),
        Table::secs(open_secs)
    );

    // --- 3. blockwise vs in-memory training ---
    let cfg = TrainConfig {
        kernel: Kernel::gaussian(0.5 / p as f64),
        stage1: Stage1Config {
            budget: 64,
            seed,
            ..Default::default()
        },
        solver: SolverOptions {
            eps: 1e-3,
            ..Default::default()
        },
        ..Default::default()
    };
    // ~One stripe of G per block at budget 64: small enough that every
    // epoch really streams multiple blocks at both workload sizes.
    let block_budget = 300_000usize;
    let src = MemorySource::new(&data);

    let (mem_model, mem_secs) = harness::time_once(|| {
        train_streaming(&src, &cfg, 0, &mut StageClock::new(), None).expect("in-memory train")
    });
    let (blk_model, blk_secs) = harness::time_once(|| {
        train_streaming(&src, &cfg, block_budget, &mut StageClock::new(), None)
            .expect("blockwise train")
    });
    let (shard_model, shard_secs) = harness::time_once(|| {
        train_streaming(&sharded, &cfg, block_budget, &mut StageClock::new(), None)
            .expect("sharded train")
    });

    // Differential check: the bounded-memory paths must reproduce the
    // resident model byte for byte.
    let reference = model_bytes(&mem_model, &dir, "mem.lpd");
    assert_eq!(
        model_bytes(&blk_model, &dir, "blk.lpd"),
        reference,
        "blockwise model diverged from the in-memory reference"
    );
    assert_eq!(
        model_bytes(&shard_model, &dir, "shard.lpd"),
        reference,
        "sharded model diverged from the in-memory reference"
    );

    let mut t = Table::new(
        "train_streaming: resident vs bounded block budget",
        &["path", "block budget", "train s", "vs resident"],
    );
    t.row(&[
        "in-memory, budget 0".into(),
        "∞".into(),
        Table::secs(mem_secs),
        "1.00x".into(),
    ]);
    t.row(&[
        "in-memory, blockwise".into(),
        format!("{block_budget} B"),
        Table::secs(blk_secs),
        format!("{:.2}x", blk_secs / mem_secs.max(1e-12)),
    ]);
    t.row(&[
        "LIBSVM shards, blockwise".into(),
        format!("{block_budget} B"),
        Table::secs(shard_secs),
        format!("{:.2}x", shard_secs / mem_secs.max(1e-12)),
    ]);
    t.print();
    t.write_tsv(&harness::report_dir().join("oocore.tsv")).ok();

    let peak_rss_mb = lpdsvm::util::mem::peak_rss_bytes()
        .map(|b| b as f64 / (1024.0 * 1024.0))
        .unwrap_or(f64::NAN);
    println!(
        "\nall three models byte-identical; process peak RSS {peak_rss_mb:.1} MiB \
         (shared across all sections — the CLI smoke enforces the per-run cap)"
    );

    let out_path = std::env::var("LPDSVM_BENCH_OOCORE_OUT")
        .unwrap_or_else(|_| "BENCH_oocore.json".to_string());
    let doc = obj(vec![
        ("bench", s("oocore")),
        ("source", s("cargo bench --bench oocore")),
        ("smoke", Json::Bool(smoke)),
        (
            "dataset",
            obj(vec![
                ("n", num(n as f64)),
                ("p", num(p as f64)),
                ("libsvm_mb", num(mb)),
                ("seed", num(seed as f64)),
            ]),
        ),
        (
            "parse",
            obj(vec![
                ("mean_s", num(stats.mean)),
                ("min_s", num(stats.min)),
                ("mb_per_s_mean", num(mb / stats.mean.max(1e-12))),
                ("mb_per_s_best", num(parse_mb_s_best)),
            ]),
        ),
        ("split_s", num(split_secs)),
        ("shard_open_s", num(open_secs)),
        (
            "train",
            obj(vec![
                ("block_budget_bytes", num(block_budget as f64)),
                ("in_memory_s", num(mem_secs)),
                ("blockwise_s", num(blk_secs)),
                ("sharded_s", num(shard_secs)),
                ("byte_identical", Json::Bool(true)),
            ]),
        ),
        ("peak_rss_mb", num(peak_rss_mb)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n").expect("write bench json");
    println!("wrote {out_path}");

    let _ = std::fs::remove_dir_all(&dir);
}
