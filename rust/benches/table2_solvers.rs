//! Paper Table 2 + Figure 2: solver comparison on the five benchmark
//! datasets — LLSVM vs exact SMO ("ThunderSVM") vs LPD-SVM, reporting
//! training time, prediction time, and test error.
//!
//! Expected shape (paper): LLSVM fast but inaccurate (guessing-level on
//! Epsilon); exact SMO accurate but 1–2 orders of magnitude slower on the
//! large sets (and aborted on ImageNet); LPD-SVM nearly as accurate as
//! exact and dramatically faster.
//!
//! `LPDSVM_BENCH_SCALE` scales n (default 0.002). The exact solver gets a
//! wall-clock budget (`LPDSVM_BENCH_EXACT_TIMEOUT`, default 300 s per
//! dataset) mirroring the paper's 42-hour abort on ImageNet.

mod harness;

use lpdsvm::baselines::exact_smo::{ExactBinaryModel, ExactSmo, ExactSmoOptions};
use lpdsvm::baselines::llsvm::{Llsvm, LlsvmOptions};
use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::dataset::Dataset;
use lpdsvm::data::synth::{PaperDataset, PaperSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::model::multiclass::error_rate;
use lpdsvm::report::Table;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::rng::Rng;
use std::time::Instant;

struct Row {
    solver: &'static str,
    dataset: String,
    train_s: Option<f64>,
    predict_s: Option<f64>,
    error: Option<f64>,
    note: String,
}

fn main() {
    let scale = harness::bench_scale();
    let seed = harness::bench_seed();
    let exact_budget: f64 = std::env::var("LPDSVM_BENCH_EXACT_TIMEOUT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300.0);
    println!("table2_solvers: scale={scale} seed={seed} exact_timeout={exact_budget}s\n");

    let mut rows: Vec<Row> = Vec::new();
    for ds in PaperDataset::all() {
        let spec = ds.spec(ds.scale_with_floor(scale, 2_000), seed);
        let data = spec.synth.generate();
        let mut rng = Rng::new(seed ^ 0xBE);
        let (train_set, test_set) = data.split(0.2, &mut rng);
        println!(
            "== {} : n_train={} n_test={} p={} classes={} B={} ==",
            ds.name(),
            train_set.len(),
            test_set.len(),
            data.dim(),
            data.n_classes,
            spec.budget
        );

        // ---- LLSVM (binary only, like the paper's table) ----
        if data.n_classes == 2 {
            let (model, t_train) = harness::time_once(|| {
                Llsvm::new(
                    Kernel::gaussian(spec.gamma),
                    LlsvmOptions {
                        c: spec.c,
                        seed,
                        ..Default::default()
                    },
                )
                .train(&train_set)
                .expect("llsvm")
            });
            let (scores, t_pred) = harness::time_once(|| model.decision(&test_set.x).unwrap());
            let err = signed_error(&scores, &test_set);
            rows.push(Row {
                solver: "LLSVM",
                dataset: ds.name().into(),
                train_s: Some(t_train),
                predict_s: Some(t_pred),
                error: Some(err),
                note: String::new(),
            });
        } else {
            rows.push(Row {
                solver: "LLSVM",
                dataset: ds.name().into(),
                train_s: None,
                predict_s: None,
                error: None,
                note: "n/a (multi-class)".into(),
            });
        }

        // ---- exact SMO ("ThunderSVM") ----
        rows.push(exact_row(ds, &spec, &train_set, &test_set, exact_budget, seed));

        // ---- LPD-SVM ----
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: spec.budget,
                seed,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                seed,
                ..Default::default()
            },
            ..Default::default()
        };
        let (model, t_train) = harness::time_once(|| train(&train_set, &cfg).expect("lpd"));
        let (preds, t_pred) = harness::time_once(|| model.predict(&test_set.x).unwrap());
        let err = error_rate(&preds, &test_set.labels);
        rows.push(Row {
            solver: "LPD-SVM",
            dataset: ds.name().into(),
            train_s: Some(t_train),
            predict_s: Some(t_pred),
            error: Some(err),
            note: format!("rank={}", model.factor.rank),
        });
    }

    // ---- Table 2 ----
    let mut t = Table::new(
        "Table 2 analogue: training/prediction time (s) and test error (%)",
        &["solver", "dataset", "train", "predict", "error %", "note"],
    );
    for r in &rows {
        t.row(&[
            r.solver.into(),
            r.dataset.clone(),
            r.train_s.map(Table::secs).unwrap_or_else(|| "-".into()),
            r.predict_s.map(Table::secs).unwrap_or_else(|| "-".into()),
            r.error.map(Table::pct).unwrap_or_else(|| "-".into()),
            r.note.clone(),
        ]);
    }
    t.print();

    // ---- Figure 2: same data as plottable TSV (log-scale in the paper) ----
    let mut fig = Table::new(
        "Figure 2 series: dataset\tsolver\ttrain_s\tpredict_s",
        &["dataset", "solver", "train_s", "predict_s"],
    );
    for r in &rows {
        if let (Some(a), Some(b)) = (r.train_s, r.predict_s) {
            fig.row(&[
                r.dataset.clone(),
                r.solver.into(),
                format!("{a}"),
                format!("{b}"),
            ]);
        }
    }
    let path = harness::report_dir().join("fig2.tsv");
    fig.write_tsv(&path).unwrap();
    println!("figure 2 series written to {}", path.display());

    // Shape assertions (who wins) — printed, not panicking, since tiny
    // scales can flip close calls.
    check_shape(&rows);
}

fn signed_error(scores: &[f32], data: &Dataset) -> f64 {
    let y = data.signed_labels();
    scores
        .iter()
        .zip(&y)
        .filter(|(s, y)| (**s > 0.0) != (**y > 0.0))
        .count() as f64
        / y.len() as f64
}

fn exact_row(
    ds: PaperDataset,
    spec: &PaperSpec,
    train_set: &Dataset,
    test_set: &Dataset,
    budget_s: f64,
    seed: u64,
) -> Row {
    let kernel = Kernel::gaussian(spec.gamma);
    let opts = ExactSmoOptions {
        c: spec.c,
        seed,
        ..Default::default()
    };
    let t0 = Instant::now();
    if train_set.n_classes == 2 {
        let model = ExactSmo::new(kernel, opts).train(train_set);
        let t_train = t0.elapsed().as_secs_f64();
        let (scores, t_pred) = harness::time_once(|| model.decision(&test_set.x));
        Row {
            solver: "ExactSMO",
            dataset: ds.name().into(),
            train_s: Some(t_train),
            predict_s: Some(t_pred),
            error: Some(signed_error(&scores, test_set)),
            note: format!("svs={}", model.coef.len()),
        }
    } else {
        // OVO with the exact solver, under a wall-clock budget (the paper's
        // ThunderSVM run on ImageNet aborted after 42 h).
        let pairs = train_set.class_pairs();
        let mut models: Vec<((u32, u32), ExactBinaryModel)> = Vec::new();
        for &(a, b) in &pairs {
            if t0.elapsed().as_secs_f64() > budget_s {
                let done = models.len();
                return Row {
                    solver: "ExactSMO",
                    dataset: ds.name().into(),
                    train_s: None,
                    predict_s: None,
                    error: None,
                    note: format!(
                        "> {budget_s:.0}s (aborted at {done}/{} pairs, {:.0}% complete)",
                        pairs.len(),
                        100.0 * done as f64 / pairs.len() as f64
                    ),
                };
            }
            let (sub, _) = train_set.ovo_subproblem(a, b);
            let model = ExactSmo::new(kernel, opts.clone()).train(&sub);
            models.push(((a, b), model));
        }
        let t_train = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let mut votes = vec![vec![0u32; train_set.n_classes]; test_set.len()];
        for ((a, b), model) in &models {
            let scores = model.decision(&test_set.x);
            for (i, &s) in scores.iter().enumerate() {
                let w = if s > 0.0 { *b } else { *a };
                votes[i][w as usize] += 1;
            }
        }
        let preds: Vec<u32> = votes
            .iter()
            .map(|v| {
                let mut best = 0usize;
                for c in 1..v.len() {
                    if v[c] > v[best] {
                        best = c;
                    }
                }
                best as u32
            })
            .collect();
        Row {
            solver: "ExactSMO",
            dataset: ds.name().into(),
            train_s: Some(t_train),
            predict_s: Some(t1.elapsed().as_secs_f64()),
            error: Some(error_rate(&preds, &test_set.labels)),
            note: format!("{} pairs", models.len()),
        }
    }
}

fn check_shape(rows: &[Row]) {
    println!("\n-- shape checks (paper's qualitative claims) --");
    for ds in PaperDataset::all() {
        let name = ds.name();
        let get = |solver: &str| {
            rows.iter()
                .find(|r| r.solver == solver && r.dataset == name)
        };
        if let (Some(exact), Some(lpd)) = (get("ExactSMO"), get("LPD-SVM")) {
            match (exact.train_s, lpd.train_s) {
                (Some(te), Some(tl)) => {
                    let speedup = te / tl.max(1e-9);
                    let acc = match (exact.error, lpd.error) {
                        (Some(ee), Some(el)) => format!(
                            "errors exact {:.2}% vs lpd {:.2}% (Δ {:+.2}pp)",
                            ee * 100.0,
                            el * 100.0,
                            (el - ee) * 100.0
                        ),
                        _ => String::new(),
                    };
                    println!("{name:<10} LPD speedup over exact: ×{speedup:.1}  {acc}");
                }
                (None, Some(_)) => {
                    println!("{name:<10} exact solver aborted (as in the paper for ImageNet); LPD completed");
                }
                _ => {}
            }
        }
    }
}
