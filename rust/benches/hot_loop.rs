//! CD hot-loop micro-benchmark: coordinate-ascent steps per second per
//! core as a function of the feature dimension B.
//!
//! Paper claim (§4): "for a realistic value like B = 10³, each CPU core
//! performs several million coordinate ascent steps per second". Each step
//! is one B-dot plus one B-axpy (≈ 4·B flops + 2·B·4 bytes of traffic), so
//! on this testbed the roofline is memory-bandwidth-bound; §Perf in
//! EXPERIMENTS.md tracks measured steps/s against that roofline.

mod harness;

use lpdsvm::linalg::Mat;
use lpdsvm::solver::{solve, ProblemView, SolverOptions};
use lpdsvm::util::rng::Rng;

fn main() {
    let seed = harness::bench_seed();
    println!("hot_loop: CD steps/second (paper: 'several million' at B=1000)\n");

    for b in [64usize, 128, 256, 512, 1024, 2048] {
        let n = 4096usize;
        let mut rng = Rng::new(seed ^ b as u64);
        let mut g = Mat::zeros(n, b);
        for v in g.data.iter_mut() {
            *v = rng.normal() as f32 * 0.2;
        }
        let y: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let rows: Vec<usize> = (0..n).collect();
        let p = ProblemView::new(&g, &rows, &y);
        // Fixed-epoch run (eps=0 never converges) to measure raw step rate;
        // shrinking off so every step does the full O(B) work.
        let opts = SolverOptions {
            c: 1.0,
            eps: 0.0,
            max_epochs: 40,
            shrinking: false,
            seed,
            ..Default::default()
        };
        let mut steps_total = 0u64;
        let stats = harness::bench_stats(1, 9, || {
            let sol = solve(&p, &opts);
            steps_total = sol.steps;
        });
        // min is the noise-robust statistic on a shared/noisy host.
        let steps_per_sec = steps_total as f64 / stats.min;
        let gb_per_sec = steps_per_sec * (2.0 * b as f64 * 4.0) / 1e9;
        harness::print_stats(
            &format!("cd_steps B={b:<5} ({steps_total} steps/run)"),
            &stats,
            Some((steps_total as f64, "steps")),
        );
        println!(
            "    → {:.2}M steps/s, effective memory traffic ≈ {:.1} GB/s",
            steps_per_sec / 1e6,
            gb_per_sec
        );
    }
}
