//! Stage-1 compute-backbone throughput: thread sweep over the parallel
//! tiled GEMM + kernel-block pipeline that assembles the factor `G`.
//!
//! Runs `LowRankFactor::compute` on a synthetic multi-class dataset for
//! threads ∈ {1, 2, 4, 8, all}, reports per-stage seconds, matrix_g
//! GFLOP/s and the speedup over the single-thread path, and asserts the
//! parallel factor is bit-identical to the serial one. Results are written
//! to `BENCH_stage1.json` (override with `LPDSVM_BENCH_STAGE1_OUT`) so the
//! perf trajectory is tracked in-repo from PR 2 onward.
//!
//!     cargo bench --bench stage1_throughput              # full workload
//!     cargo bench --bench stage1_throughput -- --smoke   # CI fast mode
//!
//! Optional regression gate: set `LPDSVM_BENCH_MIN_SPEEDUP=2.5` to fail
//! the run unless the best matrix_g speedup reaches that factor (left
//! unset on hosts whose core count cannot support it).

mod harness;

use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::{LowRankFactor, NativeBackend};
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::report::Table;
use lpdsvm::util::json::{arr, num, obj, s, Json};
use lpdsvm::util::threads;
use lpdsvm::util::timer::StageClock;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seed = harness::bench_seed();
    let cores = threads::default_threads();

    // Synthetic multi-class workload; `--smoke` keeps CI bounded while
    // still crossing the chunk, KC and NC tile boundaries.
    let (n, p, budget, chunk) = if smoke {
        (3_000, 48, 160, 256)
    } else {
        (24_000, 96, 640, 512)
    };
    let data = SynthSpec {
        name: "stage1-bench".into(),
        n,
        p,
        n_classes: 6,
        sep: 4.0,
        latent: 8,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate();
    let kernel = Kernel::gaussian(0.5 / p as f64);
    println!(
        "stage1_throughput{}: n={n} p={p} B={budget} chunk={chunk} cores={cores}\n",
        if smoke { " (smoke)" } else { "" }
    );

    let mut sweep = vec![1usize, 2, 4, 8, cores];
    sweep.sort_unstable();
    sweep.dedup();

    let mut table = Table::new(
        "stage-1 thread sweep (matrix_g = kernel block + K·W GEMM)",
        &["threads", "prep s", "matrix_g s", "GFLOP/s", "speedup"],
    );
    let mut rows_json: Vec<Json> = Vec::new();
    let mut serial_g: Option<lpdsvm::linalg::Mat> = None;
    let mut serial_secs = 0.0f64;
    let mut best_speedup = 0.0f64;

    for &t in &sweep {
        let cfg = Stage1Config {
            budget,
            chunk,
            seed,
            threads: t,
            ..Default::default()
        };
        let backend = NativeBackend::with_threads(t);
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(&data.x, kernel, &cfg, &backend, &mut clock)
            .expect("stage 1 computes");
        let prep = clock.secs("preparation");
        let mg = clock.secs("matrix_g");

        // Differential check: every thread count must reproduce the
        // serial factor bit for bit.
        if let Some(reference) = serial_g.as_ref() {
            assert_eq!(
                reference, &factor.g,
                "threads={t} produced a different G than threads=1"
            );
        } else {
            serial_g = Some(factor.g.clone());
            serial_secs = mg;
        }

        // matrix_g FLOPs: per row, B dots of dim p for the kernel block
        // (2·B·p) plus the B×rank whitening GEMM (2·B·rank).
        let flops_per_row = 2.0 * budget as f64 * (p as f64 + factor.rank as f64);
        let flops = n as f64 * flops_per_row;
        let gflops = flops / mg.max(1e-12) / 1e9;
        let speedup = serial_secs / mg.max(1e-12);
        best_speedup = best_speedup.max(speedup);
        table.row(&[
            t.to_string(),
            Table::secs(prep),
            Table::secs(mg),
            format!("{gflops:.2}"),
            format!("{speedup:.2}x"),
        ]);
        rows_json.push(obj(vec![
            ("threads", num(t as f64)),
            ("preparation_s", num(prep)),
            ("matrix_g_s", num(mg)),
            ("gflops", num(gflops)),
            ("speedup_vs_1thread", num(speedup)),
            ("rank", num(factor.rank as f64)),
        ]));
    }

    table.print();
    table
        .write_tsv(&harness::report_dir().join("stage1_throughput.tsv"))
        .ok();
    println!(
        "\nbest matrix_g speedup: {best_speedup:.2}x on {cores} cores \
         (acceptance target: ≥ 3x at 8 threads on an ≥ 8-core host)"
    );

    let out_path = std::env::var("LPDSVM_BENCH_STAGE1_OUT")
        .unwrap_or_else(|_| "BENCH_stage1.json".to_string());
    let doc = obj(vec![
        ("bench", s("stage1_throughput")),
        ("source", s("cargo bench --bench stage1_throughput")),
        ("smoke", Json::Bool(smoke)),
        (
            "dataset",
            obj(vec![
                ("n", num(n as f64)),
                ("p", num(p as f64)),
                ("classes", num(6.0)),
                ("budget", num(budget as f64)),
                ("chunk", num(chunk as f64)),
                ("kernel", s(kernel.name())),
                ("seed", num(seed as f64)),
            ]),
        ),
        ("host_cores", num(cores as f64)),
        ("results", arr(rows_json)),
        ("best_speedup_vs_1thread", num(best_speedup)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n").expect("write bench json");
    println!("wrote {out_path}");

    if let Some(min) = std::env::var("LPDSVM_BENCH_MIN_SPEEDUP")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
    {
        assert!(
            best_speedup >= min,
            "matrix_g speedup regression: best {best_speedup:.2}x < required {min:.2}x"
        );
    }
}
