//! Serving throughput: micro-batched engine vs naive per-request loop,
//! plus admission control under open-loop overload.
//!
//! The acceptance workload for the `serve` subsystem: a synthetic OVO
//! problem, ≥ 10k single-row requests, engine batch caps swept over
//! {1, 8, 64, 256}. The naive baseline is what the repo offered before
//! the subsystem existed — one blocking `predict()` per request on one
//! thread. The engine should clear 4× at the larger batch sizes: one
//! stage-1 GEMM per batch amortizes the landmark/whitening traffic that
//! the naive loop re-reads per row, and scoring fans across all cores.
//! The third section saturates a deliberately under-provisioned engine
//! (one worker, bounded queue) and asserts the queue never exceeds its
//! cap and the excess is shed explicitly, reporting accepted-request
//! p50/p99.
//!
//! The final section is the **two-tenant overload**: one tenant saturates
//! the engine with unpaced traffic while a closed-loop probe plays the
//! cold tenant. Run once with both through a *shared* queue (the cold
//! probe rides the hot tenant's sub-queue — the PR 4 single-FIFO
//! behaviour) and once with per-model queues, recording the cold probe's
//! completions, sheds, and p99 in both. The fairness contract asserted:
//! with its own sub-queue the cold tenant completes requests and sheds
//! nothing while the hot tenant sheds.
//!
//!     cargo bench --bench serve_throughput
//!     LPDSVM_SERVE_REQUESTS=50000 cargo bench --bench serve_throughput

mod harness;

use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::{FeatureStyle, SynthSpec};
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::report::Table;
use lpdsvm::serve::{HttpOptions, HttpServer, IoModel, ModelRegistry, ServeConfig, ServeEngine};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let seed = harness::bench_seed();
    let n_requests: usize = std::env::var("LPDSVM_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);

    // Synthetic OVO workload: 6 classes → 15 binary heads.
    let data = SynthSpec {
        name: "serve-bench".into(),
        n: 2000,
        p: 24,
        n_classes: 6,
        sep: 5.0,
        latent: 6,
        noise: 1.0,
        style: FeatureStyle::Dense,
        seed,
    }
    .generate();
    let cfg = TrainConfig {
        stage1: Stage1Config {
            budget: 128,
            seed,
            ..Default::default()
        },
        ..Default::default()
    };
    let model = train(&data, &cfg).expect("bench model trains");
    println!(
        "serve_throughput: {} requests against a {}-class model (rank {}, {} heads)\n",
        n_requests,
        data.n_classes,
        model.factor.rank,
        model.heads.len()
    );

    let rows: Vec<Vec<(u32, f32)>> = (0..data.len()).map(|i| data.x.row_entries(i)).collect();

    // --- naive baseline: blocking single-row predict, one thread ---
    let expected = model.predict(&data.x).expect("baseline predictions");
    let (naive_err, naive_secs) = harness::time_once(|| {
        let mut mismatches = 0usize;
        for i in 0..n_requests {
            let j = i % rows.len();
            let x = SparseMatrix::from_rows(data.dim(), &[rows[j].clone()]);
            let pred = model.predict(&x).expect("naive predict");
            if pred[0] != expected[j] {
                mismatches += 1;
            }
        }
        mismatches
    });
    let naive_rps = n_requests as f64 / naive_secs;
    assert_eq!(naive_err, 0, "naive loop must agree with batch predict");
    println!(
        "naive per-request loop: {} s  →  {:.0} req/s (1 thread, batch size 1)\n",
        Table::secs(naive_secs),
        naive_rps
    );

    // --- engine sweep over batch caps ---
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", model);
    let mut t = Table::new(
        "micro-batched serving vs naive loop",
        &[
            "max_batch", "req/s", "speedup", "p50 ms", "p99 ms", "mean batch", "batches",
        ],
    );
    let mut best_speedup = 0.0f64;
    for max_batch in [1usize, 8, 64, 256] {
        let engine = ServeEngine::start(
            Arc::clone(&registry),
            ServeConfig {
                max_batch,
                max_wait: Duration::from_micros(200),
                workers: 0, // one per core
                ..ServeConfig::default()
            },
        );
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..n_requests)
            .map(|i| engine.submit("m", &rows[i % rows.len()]))
            .collect();
        let mut mismatches = 0usize;
        for (i, ticket) in tickets.iter().enumerate() {
            let pred = ticket.wait().expect("engine prediction");
            if pred.label != expected[i % rows.len()] {
                mismatches += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(mismatches, 0, "engine must agree with batch predict");
        let m = engine.metrics();
        let rps = n_requests as f64 / secs;
        let speedup = rps / naive_rps;
        best_speedup = best_speedup.max(speedup);
        t.row(&[
            max_batch.to_string(),
            format!("{rps:.0}"),
            format!("{speedup:.2}x"),
            format!("{:.3}", m.latency_us.quantile(0.50) as f64 / 1e3),
            format!("{:.3}", m.latency_us.quantile(0.99) as f64 / 1e3),
            format!("{:.1}", m.batch_size.mean()),
            m.batches.load(std::sync::atomic::Ordering::Relaxed).to_string(),
        ]);
        engine.shutdown();
    }
    t.print();
    t.write_tsv(&harness::report_dir().join("serve_throughput.tsv"))
        .ok();
    println!(
        "best speedup over the naive loop: {best_speedup:.1}x (acceptance target: ≥ 4x at \
         batch 64–256 on a multi-core host)\n"
    );

    // --- admission control under open-loop overload ---
    // One worker, small batches, a bounded queue, unpaced arrivals: the
    // submitter outruns scoring by construction, so without admission
    // control the queue (and tail latency) would grow without bound. The
    // acceptance contract: the queue never exceeds its cap, the engine
    // sheds the excess explicitly, and the p99 of *accepted* requests
    // stays bounded by the backlog the cap permits.
    let max_queue = 256usize;
    let engine = ServeEngine::start(
        Arc::clone(&registry),
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(200),
            workers: 1,
            max_queue,
            ..ServeConfig::default()
        },
    );
    let n_sat = n_requests.max(20_000);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_sat)
        .map(|i| engine.submit("m", &rows[i % rows.len()]))
        .collect();
    let mut accepted = 0u64;
    let mut shed = 0u64;
    for ticket in &tickets {
        match ticket.wait() {
            Ok(_) => accepted += 1,
            Err(e) if e.is_shed() => shed += 1,
            Err(e) => panic!("unexpected serve error under saturation: {e}"),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = engine.metrics();
    let queue_max = m.queue_depth_max.load(std::sync::atomic::Ordering::Relaxed);
    let rejected_full = m.rejected_full.load(std::sync::atomic::Ordering::Relaxed);
    let shed_expired = m.shed_expired.load(std::sync::atomic::Ordering::Relaxed);
    let p99_ms = m.latency_us.quantile(0.99) as f64 / 1e3;
    assert!(
        queue_max <= max_queue as u64,
        "queue grew past its cap under overload: {queue_max} > {max_queue}"
    );
    assert_eq!(
        rejected_full + shed_expired,
        shed,
        "every shed ticket must be counted in rejected_full/shed_expired"
    );
    assert!(
        shed > 0,
        "open-loop overload with one worker should overflow a {max_queue}-slot queue"
    );
    println!(
        "saturation (workers=1, max_batch=32, max_queue={max_queue}): {n_sat} submitted in \
         {secs:.2} s — {accepted} accepted, {shed} shed (rejected_full={rejected_full}, \
         shed_expired={shed_expired}), queue high-water {queue_max}, accepted p50 {:.3} ms, \
         p99 {p99_ms:.3} ms",
        m.latency_us.quantile(0.50) as f64 / 1e3
    );
    engine.shutdown();

    // --- two-tenant overload: shared queue vs per-model fairness ---
    // The hot tenant saturates an under-provisioned engine open-loop; a
    // closed-loop probe (≤ 1 request in flight) plays the cold tenant.
    // "shared" routes the probe through the hot tenant's own sub-queue —
    // exactly the PR 4 single-FIFO topology, where the probe competes
    // with the hot backlog for queue slots. "fair" gives the probe its
    // own sub-queue under the DRR scheduler.
    println!("\ntwo-tenant overload (workers=1, max_batch=32, max_queue=256 per model):");
    let model_arc = Arc::clone(registry.get("m").expect("registered above").model());
    let registry2 = Arc::new(ModelRegistry::new());
    registry2.insert_arc("hot", Arc::clone(&model_arc));
    registry2.insert_arc("cold", model_arc);
    let mut t = Table::new(
        "cold tenant under hot-tenant saturation",
        &["scenario", "cold done", "cold shed", "cold p99 ms", "hot shed"],
    );
    for (scenario, probe_target) in [("shared queue", "hot"), ("per-model DRR", "cold")] {
        let engine = Arc::new(ServeEngine::start(
            Arc::clone(&registry2),
            ServeConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(200),
                workers: 1,
                max_queue: 256,
                ..ServeConfig::default()
            },
        ));
        let hot_done = Arc::new(AtomicBool::new(false));
        let hot_engine = Arc::clone(&engine);
        let hot_rows = rows.clone();
        let hot_flag = Arc::clone(&hot_done);
        let hot = std::thread::spawn(move || {
            let tickets: Vec<_> = (0..n_sat)
                .map(|i| hot_engine.submit("hot", &hot_rows[i % hot_rows.len()]))
                .collect();
            let mut shed = 0u64;
            for t in &tickets {
                if matches!(t.wait(), Err(e) if e.is_shed()) {
                    shed += 1;
                }
            }
            hot_flag.store(true, Ordering::Release);
            shed
        });
        let mut cold_done = 0u64;
        let mut cold_shed = 0u64;
        let mut cold_lat_us: Vec<u64> = Vec::new();
        while !hot_done.load(Ordering::Acquire) {
            match engine.submit(probe_target, &rows[0]).wait() {
                Ok(p) => {
                    cold_done += 1;
                    cold_lat_us.push(p.total_us);
                }
                Err(e) if e.is_shed() => {
                    // Back off like a real client so the rejected probe
                    // does not spin on the queue lock.
                    cold_shed += 1;
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => panic!("unexpected cold-probe error: {e}"),
            }
        }
        let hot_shed = hot.join().expect("hot generator");
        cold_lat_us.sort_unstable();
        let p99 = cold_lat_us
            .get((cold_lat_us.len().saturating_sub(1)) * 99 / 100)
            .copied()
            .unwrap_or(0);
        t.row(&[
            scenario.into(),
            cold_done.to_string(),
            cold_shed.to_string(),
            format!("{:.3}", p99 as f64 / 1e3),
            hot_shed.to_string(),
        ]);
        if probe_target == "cold" {
            // The fairness contract this PR exists for.
            assert_eq!(
                cold_shed, 0,
                "cold tenant shed behind its own sub-queue — isolation broken"
            );
            assert!(cold_done > 0, "cold tenant starved under per-model DRR");
            assert!(
                hot_shed > 0,
                "hot tenant never shed — the overload did not saturate"
            );
        }
        engine.shutdown();
    }
    t.print();
    t.write_tsv(&harness::report_dir().join("serve_fairness.tsv"))
        .ok();

    // --- HTTP front-end: thread-per-connection vs evented loop ---
    // The same predict workload pushed through the wire on keep-alive
    // connections: C closed-loop clients, each re-using one connection
    // for its whole share of the requests. Client-side latency includes
    // parse + dispatch + engine + response drain, so this measures the
    // connection plane, not just the engine. The evented loop must hold
    // its own against the thread pool at this (modest) connection count
    // — its payoff is holding thousands of connections on one thread,
    // which `tests/serve_http_adversarial.rs` and the CI drill cover.
    const HTTP_CLIENTS: usize = 32;
    let io_models: &[IoModel] = if cfg!(target_os = "linux") {
        &[IoModel::Threads, IoModel::Evented]
    } else {
        &[IoModel::Threads]
    };
    println!("\nHTTP front-end ({HTTP_CLIENTS} keep-alive client connections):");
    let mut t = Table::new(
        "http connection plane: threads vs evented",
        &["io model", "req/s", "p50 ms", "p99 ms"],
    );
    for io in io_models {
        let engine = Arc::new(ServeEngine::start(
            Arc::clone(&registry),
            ServeConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(200),
                workers: 0, // one per core
                ..ServeConfig::default()
            },
        ));
        let server = HttpServer::bind_with_opts(
            Arc::clone(&engine),
            "127.0.0.1:0",
            HttpOptions {
                io_model: *io,
                ..HttpOptions::default()
            },
        )
        .expect("http server binds");
        let addr = server.addr();
        // Pre-rendered keep-alive request frames over a rotating row set,
        // so the clients spend their time on the wire, not on JSON.
        let frames: Arc<Vec<Vec<u8>>> = Arc::new(
            (0..256)
                .map(|j| {
                    let body = single_row_body(&rows[j % rows.len()]);
                    format!(
                        "POST /v1/models/m:predict HTTP/1.1\r\nhost: b\r\ncontent-length: {}\r\n\r\n{body}",
                        body.len()
                    )
                    .into_bytes()
                })
                .collect(),
        );
        let per_client = (n_requests / HTTP_CLIENTS).max(1);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..HTTP_CLIENTS)
            .map(|c| {
                let frames = Arc::clone(&frames);
                std::thread::spawn(move || {
                    let stream = TcpStream::connect(addr).expect("client connects");
                    stream
                        .set_read_timeout(Some(Duration::from_secs(60)))
                        .expect("read timeout");
                    stream.set_nodelay(true).ok();
                    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                    let mut writer = stream;
                    let mut lat_us = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let frame = &frames[(c + i * 31) % frames.len()];
                        let q0 = Instant::now();
                        writer.write_all(frame).expect("request written");
                        let status = read_http_response(&mut reader);
                        assert_eq!(status, 200, "predict over http failed");
                        lat_us.push(q0.elapsed().as_micros() as u64);
                    }
                    lat_us
                })
            })
            .collect();
        let mut lat_us: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect();
        let secs = t0.elapsed().as_secs_f64();
        lat_us.sort_unstable();
        let q = |f: f64| lat_us[((lat_us.len() - 1) as f64 * f) as usize] as f64 / 1e3;
        t.row(&[
            format!("{io:?}").to_lowercase(),
            format!("{:.0}", lat_us.len() as f64 / secs),
            format!("{:.3}", q(0.50)),
            format!("{:.3}", q(0.99)),
        ]);
        server.shutdown();
        engine.shutdown();
    }
    t.print();
    t.write_tsv(&harness::report_dir().join("serve_http_io.tsv"))
        .ok();
}

/// Single-row predict body in the batch (`rows`) shape.
fn single_row_body(row: &[(u32, f32)]) -> String {
    let mut body = String::from("{\"rows\": [[");
    for (i, &(c, v)) in row.iter().enumerate() {
        if i > 0 {
            body.push_str(", ");
        }
        body.push_str(&format!("[{c}, {v}]"));
    }
    body.push_str("]]}");
    body
}

/// Read one length-framed HTTP response off a keep-alive stream and
/// return its status code (the body is drained and discarded).
fn read_http_response<R: BufRead>(reader: &mut R) -> u16 {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).expect("header line");
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("content-length");
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    status
}
