//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Warm starts along the C path** (paper §4: part of the table-3
//!    speed-up) — grid search with warm_start on/off.
//! 2. **Landmark selection** — uniform (paper default) vs kernel
//!    k-means++ (the data-dependent alternative the paper cites [26]).
//! 3. **Eigenvalue truncation ε_rank** (paper §4: dropping near-machine-
//!    precision eigendirections) — effective rank and error vs threshold.

mod harness;

use lpdsvm::coordinator::grid::{grid_search, GridConfig};
use lpdsvm::coordinator::train::{train, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::landmarks::LandmarkStrategy;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::report::Table;
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::rng::Rng;

fn main() {
    let scale = harness::bench_scale();
    let seed = harness::bench_seed();
    println!("ablations: scale={scale} seed={seed}\n");
    warm_start_ablation(scale, seed);
    landmark_ablation(scale, seed);
    rank_truncation_ablation(scale, seed);
}

fn warm_start_ablation(scale: f64, seed: u64) {
    let spec = PaperDataset::Adult.spec(
        PaperDataset::Adult.scale_with_floor(scale, 2_000),
        seed,
    );
    let data = spec.synth.generate();
    let base = TrainConfig {
        kernel: Kernel::gaussian(spec.gamma),
        stage1: Stage1Config {
            budget: spec.budget,
            seed,
            ..Default::default()
        },
        solver: SolverOptions {
            seed,
            ..Default::default()
        },
        ..Default::default()
    };
    let grid = |warm| GridConfig {
        c_values: (0..10).map(|i| 2f64.powi(i)).collect(),
        gamma_values: vec![spec.gamma],
        cv_folds: 5,
        seed,
        warm_start: warm,
    };
    let (warm, t_warm) = harness::time_once(|| grid_search(&data, &base, &grid(true)).unwrap());
    let (cold, t_cold) = harness::time_once(|| grid_search(&data, &base, &grid(false)).unwrap());
    let mut t = Table::new(
        "ablation 1: warm starts along the C path (adult analogue)",
        &["variant", "total s", "best err %", "speed-up"],
    );
    t.row(&[
        "warm".into(),
        Table::secs(t_warm),
        Table::pct(warm.best_error),
        format!("x{:.2}", t_cold / t_warm.max(1e-9)),
    ]);
    t.row(&[
        "cold".into(),
        Table::secs(t_cold),
        Table::pct(cold.best_error),
        "x1.00".into(),
    ]);
    t.print();
    assert!(
        (warm.best_error - cold.best_error).abs() < 0.05,
        "warm starts changed the tuned error materially"
    );
}

fn landmark_ablation(scale: f64, seed: u64) {
    let spec = PaperDataset::Epsilon.spec(
        PaperDataset::Epsilon.scale_with_floor(scale, 2_000),
        seed,
    );
    let data = spec.synth.generate();
    let mut rng = Rng::new(seed);
    let (train_set, test_set) = data.split(0.25, &mut rng);
    let mut t = Table::new(
        "ablation 2: landmark selection (epsilon analogue, small budget)",
        &["strategy", "budget", "stage1 s", "test err %"],
    );
    // Small budget makes the selection strategy matter.
    for (name, strategy) in [
        ("uniform", LandmarkStrategy::Uniform),
        ("kmeans++", LandmarkStrategy::KmeansPlusPlus),
    ] {
        for budget in [32usize, 96] {
            let cfg = TrainConfig {
                kernel: Kernel::gaussian(spec.gamma),
                stage1: Stage1Config {
                    budget,
                    strategy,
                    seed,
                    ..Default::default()
                },
                solver: SolverOptions {
                    c: spec.c,
                    seed,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (model, secs) = harness::time_once(|| train(&train_set, &cfg).unwrap());
            let err = model.error_rate(&test_set.x, &test_set.labels).unwrap();
            t.row(&[
                name.into(),
                budget.to_string(),
                Table::secs(secs),
                Table::pct(err),
            ]);
        }
    }
    t.print();
}

fn rank_truncation_ablation(scale: f64, seed: u64) {
    let spec = PaperDataset::Adult.spec(
        PaperDataset::Adult.scale_with_floor(scale, 2_000),
        seed,
    );
    let data = spec.synth.generate();
    let mut rng = Rng::new(seed ^ 1);
    let (train_set, test_set) = data.split(0.25, &mut rng);
    let mut t = Table::new(
        "ablation 3: eigenvalue truncation threshold (adult analogue)",
        &["eps_rank", "rank (of B)", "train s", "test err %"],
    );
    for eps_rank in [1e-12, 1e-8, 1e-6, 1e-3, 1e-1] {
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: spec.budget,
                eps_rank,
                seed,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                seed,
                ..Default::default()
            },
            ..Default::default()
        };
        let (model, secs) = harness::time_once(|| train(&train_set, &cfg).unwrap());
        let err = model.error_rate(&test_set.x, &test_set.labels).unwrap();
        t.row(&[
            format!("{eps_rank:.0e}"),
            format!("{}/{}", model.factor.rank, spec.budget),
            Table::secs(secs),
            Table::pct(err),
        ]);
    }
    t.print();
    println!(
        "expected shape: rank shrinks as eps_rank grows; error flat until the\n\
         threshold eats informative directions (paper §4: dropping noisy\n\
         eigendirections is free, dropping signal is not)."
    );
}
