//! Paper Figure 3: per-stage timing breakdown — "preparation" (landmarks +
//! K_BB + eigh), "computation of the matrix G", and "linear SVM training"
//! — on the native backend (the paper's CPU) and the PJRT artifact backend
//! (the paper's GPU; see DESIGN.md §Hardware-Adaptation).
//!
//! Expected shape: the batch-friendly stages (preparation's K_BB, matrix G)
//! benefit from the compiled/fused artifact path, while the inherently
//! sequential SMO loop is a pure-L3 affair where the native path wins —
//! the paper's central CPU-vs-GPU observation.

mod harness;

use lpdsvm::coordinator::train::{train_with_backend, TrainConfig};
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::Stage1Config;
use lpdsvm::report::Table;
use lpdsvm::runtime::{AccelBackend, Runtime};
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::timer::StageClock;

fn main() {
    let scale = harness::bench_scale();
    let seed = harness::bench_seed();
    println!("fig3_breakdown: scale={scale} seed={seed}\n");

    let runtime = match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("PJRT backend unavailable ({e}); emitting native-only breakdown");
            None
        }
    };

    let mut t = Table::new(
        "Figure 3 analogue: stage breakdown (seconds)",
        &["dataset", "backend", "preparation", "matrix G", "linear train", "total"],
    );
    let mut fig = Table::new(
        "fig3 series",
        &["dataset", "backend", "stage", "seconds"],
    );

    for ds in PaperDataset::all() {
        let spec = ds.spec(ds.scale_with_floor(scale, 2_000), seed);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: spec.budget,
                seed,
                chunk: 256,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                seed,
                ..Default::default()
            },
            ..Default::default()
        };

        let mut run = |label: &str, backend: &dyn lpdsvm::lowrank::Stage1Backend| {
            let mut clock = StageClock::new();
            match train_with_backend(&data, &cfg, backend, &mut clock) {
                Ok(_) => {
                    let prep = clock.secs("preparation");
                    let g = clock.secs("matrix_g");
                    let lin = clock.secs("linear_train");
                    t.row(&[
                        ds.name().into(),
                        label.into(),
                        Table::secs(prep),
                        Table::secs(g),
                        Table::secs(lin),
                        Table::secs(prep + g + lin),
                    ]);
                    for (stage, secs) in
                        [("preparation", prep), ("matrix_g", g), ("linear_train", lin)]
                    {
                        fig.row(&[
                            ds.name().into(),
                            label.into(),
                            stage.into(),
                            format!("{secs}"),
                        ]);
                    }
                }
                Err(e) => {
                    // The paper's figure 3 likewise has missing GPU bars
                    // where G does not fit in GPU memory; here the analogue
                    // is a dataset exceeding the largest artifact variant.
                    t.row(&[
                        ds.name().into(),
                        label.into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        format!("unavailable: {e}"),
                    ]);
                }
            }
        };

        run("native", &NativeBackend::default());
        if let Some(rt) = &runtime {
            let accel = AccelBackend::new(rt);
            run("pjrt", &accel);
        }
    }

    println!();
    t.print();
    let path = harness::report_dir().join("fig3.tsv");
    fig.write_tsv(&path).unwrap();
    println!("figure 3 series written to {}", path.display());
}
