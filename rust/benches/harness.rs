//! Shared bench harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that uses these helpers
//! to run the paper's workloads and print the corresponding table/figure.
//! Scale is controlled by `LPDSVM_BENCH_SCALE` (fraction of the paper's
//! dataset sizes, default 0.002 so `cargo bench` completes on one core)
//! and `LPDSVM_BENCH_SEED`.
//!
//! Bench `println!` output is intentional: the tables/figures ARE the
//! result, and CI archives them from stdout alongside the JSON
//! artifacts. Diagnostics belong in `lpdsvm::obs::log`, not here.

#![allow(dead_code)]

use std::time::Instant;

/// Benchmark scale factor relative to the paper's dataset sizes.
pub fn bench_scale() -> f64 {
    std::env::var("LPDSVM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.002)
}

pub fn bench_seed() -> u64 {
    std::env::var("LPDSVM_BENCH_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(42)
}

/// Directory for TSV figure exports.
pub fn report_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("target/bench-reports");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Time a closure once (macro-benchmark: whole training runs, as in the
/// paper's tables).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Statistics over repeated timed runs (micro-benchmarks).
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub stddev: f64,
    pub samples: usize,
}

/// Run `f` `samples` times after `warmup` runs and report wall-time stats.
pub fn bench_stats(warmup: usize, samples: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
    Stats {
        mean,
        median: times[times.len() / 2],
        min: times[0],
        stddev: var.sqrt(),
        samples,
    }
}

/// Pretty print a stats line, criterion-style.
pub fn print_stats(label: &str, s: &Stats, unit_per_iter: Option<(f64, &str)>) {
    let extra = match unit_per_iter {
        Some((count, unit)) => format!("  |  {:.2e} {unit}/s", count / s.mean),
        None => String::new(),
    };
    println!(
        "{label:<42} mean {:>10.4}s  median {:>10.4}s  min {:>10.4}s  ±{:>8.4}s ({} runs){extra}",
        s.mean, s.median, s.min, s.stddev, s.samples
    );
}
