//! Model persistence: a self-describing binary container (serde is not
//! available offline, and the matrices are large enough that JSON would be
//! wasteful anyway).
//!
//! Layout: magic "LPDSVM2\0", a JSON header (lengths + kernel + kind),
//! then raw little-endian f32/f64 payload sections in header order, then
//! a CRC-32 footer over everything before it. Writes are atomic
//! (temp + fsync + rename via [`crate::util::fsio`]), so a crash
//! mid-save — exercised through the `model.save.after_tmp_write` fault
//! point — can never leave a truncated or torn model on disk: either the
//! old file survives intact or the new one is complete.

use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::lowrank::LowRankFactor;
use crate::model::multiclass::{BinaryHead, MulticlassModel};
use crate::model::ModelKind;
use crate::util::fsio;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LPDSVM2\0";

fn kernel_to_json(k: &Kernel) -> Json {
    match *k {
        Kernel::Gaussian { gamma } => obj(vec![("type", s("gaussian")), ("gamma", num(gamma))]),
        Kernel::Polynomial { gamma, coef0, degree } => obj(vec![
            ("type", s("polynomial")),
            ("gamma", num(gamma)),
            ("coef0", num(coef0)),
            ("degree", num(degree as f64)),
        ]),
        Kernel::Tanh { gamma, coef0 } => obj(vec![
            ("type", s("tanh")),
            ("gamma", num(gamma)),
            ("coef0", num(coef0)),
        ]),
        Kernel::Linear => obj(vec![("type", s("linear"))]),
    }
}

fn kernel_from_json(j: &Json) -> Result<Kernel> {
    let t = j
        .get("type")
        .and_then(|t| t.as_str())
        .context("kernel.type missing")?;
    let g = |key: &str| -> Result<f64> {
        j.get(key)
            .and_then(|v| v.as_f64())
            .with_context(|| format!("kernel.{key} missing"))
    };
    Ok(match t {
        "gaussian" => Kernel::Gaussian { gamma: g("gamma")? },
        "polynomial" => Kernel::Polynomial {
            gamma: g("gamma")?,
            coef0: g("coef0")?,
            degree: g("degree")? as u32,
        },
        "tanh" => Kernel::Tanh {
            gamma: g("gamma")?,
            coef0: g("coef0")?,
        },
        "linear" => Kernel::Linear,
        other => bail!("unknown kernel type '{other}'"),
    })
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a model to `path`.
pub fn save(model: &MulticlassModel, path: &Path) -> Result<()> {
    let f = &model.factor;
    let kind_json = match model.kind {
        ModelKind::Binary => obj(vec![("type", s("binary"))]),
        ModelKind::OneVsOne { n_classes } => obj(vec![
            ("type", s("ovo")),
            ("n_classes", num(n_classes as f64)),
        ]),
    };
    let heads_json = arr(model
        .heads
        .iter()
        .map(|h| {
            obj(vec![
                ("a", num(h.pair.0 as f64)),
                ("b", num(h.pair.1 as f64)),
                ("objective", num(h.objective)),
                ("converged", Json::Bool(h.converged)),
                ("sv_count", num(h.sv_count as f64)),
                ("steps", num(h.steps as f64)),
            ])
        })
        .collect());
    let header = obj(vec![
        ("kind", kind_json),
        ("kernel", kernel_to_json(&f.kernel)),
        ("rank", num(f.rank as f64)),
        ("budget", num(f.landmarks.rows as f64)),
        ("dim", num(f.landmarks.cols as f64)),
        ("heads", heads_json),
        (
            "eigenvalues",
            arr(f.eigenvalues.iter().map(|&v| num(v)).collect()),
        ),
    ]);
    let header_bytes = header.to_string().into_bytes();

    // Build the whole image in memory, then hand it to the atomic
    // checksummed writer — a model is a few MB at most, and the in-memory
    // detour is what makes the on-disk state all-or-nothing.
    let mut payload = Vec::with_capacity(
        header_bytes.len() + 16 + 4 * (f.landmarks.data.len() + f.whiten.data.len()),
    );
    payload.extend_from_slice(&(header_bytes.len() as u64).to_le_bytes());
    payload.extend_from_slice(&header_bytes);
    // Payload: landmarks, whiten, each head's w. (G itself is NOT saved —
    // it is training-time state; prediction only needs landmarks + W.)
    write_f32s(&mut payload, &f.landmarks.data)?;
    write_f32s(&mut payload, &f.whiten.data)?;
    for h in &model.heads {
        write_f32s(&mut payload, &h.w)?;
    }
    fsio::write_checksummed(path, MAGIC, &payload, "model.save.after_tmp_write")
        .with_context(|| format!("saving model to {}", path.display()))
}

/// Load a model from `path`. The training-time `G` matrix is not stored;
/// the loaded factor has an empty `g` (prediction does not need it).
///
/// The whole file is checksummed: a truncated or bit-flipped model is
/// rejected with a clear error instead of deserializing into garbage.
pub fn load(path: &Path) -> Result<MulticlassModel> {
    let payload = fsio::read_checksummed(path, MAGIC)
        .with_context(|| format!("loading model from {}", path.display()))?
        .with_context(|| format!("model file {} does not exist", path.display()))?;
    let mut input: &[u8] = &payload;
    let mut len8 = [0u8; 8];
    input.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbytes = vec![0u8; hlen];
    input.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)?;

    let rank = header.get("rank").and_then(|v| v.as_usize()).context("rank")?;
    let budget = header
        .get("budget")
        .and_then(|v| v.as_usize())
        .context("budget")?;
    let dim = header.get("dim").and_then(|v| v.as_usize()).context("dim")?;
    let kernel = kernel_from_json(header.get("kernel").context("kernel")?)?;
    let kind = match header
        .get("kind")
        .and_then(|k| k.get("type"))
        .and_then(|t| t.as_str())
    {
        Some("binary") => ModelKind::Binary,
        Some("ovo") => ModelKind::OneVsOne {
            n_classes: header
                .get("kind")
                .and_then(|k| k.get("n_classes"))
                .and_then(|v| v.as_usize())
                .context("kind.n_classes")?,
        },
        _ => bail!("bad model kind"),
    };
    let eigenvalues: Vec<f64> = header
        .get("eigenvalues")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
        .unwrap_or_default();

    let landmarks = Mat::from_vec(budget, dim, read_f32s(&mut input, budget * dim)?);
    let landmark_sq = landmarks.row_sq_norms();
    let whiten = Mat::from_vec(budget, rank, read_f32s(&mut input, budget * rank)?);

    let heads_meta = header
        .get("heads")
        .and_then(|v| v.as_arr())
        .context("heads")?;
    let mut heads = Vec::with_capacity(heads_meta.len());
    for hm in heads_meta {
        let w = read_f32s(&mut input, rank)?;
        heads.push(BinaryHead {
            pair: (
                hm.get("a").and_then(|v| v.as_usize()).context("head.a")? as u32,
                hm.get("b").and_then(|v| v.as_usize()).context("head.b")? as u32,
            ),
            w,
            objective: hm.get("objective").and_then(|v| v.as_f64()).unwrap_or(0.0),
            converged: matches!(hm.get("converged"), Some(Json::Bool(true))),
            sv_count: hm.get("sv_count").and_then(|v| v.as_usize()).unwrap_or(0),
            steps: hm.get("steps").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        });
    }

    let factor = LowRankFactor {
        g: Mat::zeros(0, rank),
        landmarks,
        landmark_sq,
        whiten,
        rank,
        eigenvalues,
        kernel,
        landmark_idx: Vec::new(),
    };
    Ok(MulticlassModel {
        factor,
        heads,
        kind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::{train, TrainConfig};
    use crate::data::synth::PaperDataset;
    use crate::lowrank::Stage1Config;
    use crate::solver::SolverOptions;

    #[test]
    fn save_load_roundtrip_predictions_match() {
        let spec = PaperDataset::Adult.spec(0.01, 5);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 32,
                ..Default::default()
            },
            solver: SolverOptions::default(),
            ..Default::default()
        };
        let model = train(&data, &cfg).unwrap();
        let preds = model.predict(&data.x).unwrap();

        let dir = std::env::temp_dir().join("lpdsvm_model_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.lpd");
        save(&model, &path).unwrap();
        let loaded = load(&path).unwrap();
        let preds2 = loaded.predict(&data.x).unwrap();
        assert_eq!(preds, preds2);
        assert_eq!(loaded.kind, model.kind);
        assert_eq!(loaded.factor.rank, model.factor.rank);
        std::fs::remove_file(&path).ok();
    }

    fn tiny_model() -> MulticlassModel {
        let spec = PaperDataset::Adult.spec(0.005, 8);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            solver: SolverOptions::default(),
            ..Default::default()
        };
        train(&data, &cfg).unwrap()
    }

    #[test]
    fn crash_during_save_preserves_previous_model() {
        let _serial = crate::util::fault::test_lock();
        let dir = std::env::temp_dir().join(format!("lpdsvm_io_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.lpd");
        let model = tiny_model();
        save(&model, &path).unwrap();
        let before = std::fs::read(&path).unwrap();

        // Crash in the window between the temp write and the rename: the
        // published file must be byte-identical to the previous save.
        crate::util::fault::set_schedule("model.save.after_tmp_write=error").unwrap();
        let err = save(&model, &path).unwrap_err();
        crate::util::fault::clear();
        assert!(err.to_string().contains("saving model"), "{err:#}");
        assert_eq!(std::fs::read(&path).unwrap(), before, "old model was torn");
        load(&path).unwrap();
        // And no temp litter left behind for the retry to trip over.
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .contains(".tmp.")
            })
            .collect();
        assert!(litter.is_empty(), "temp files left: {litter:?}");
        save(&model, &path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_model_is_rejected_not_misparsed() {
        let dir = std::env::temp_dir().join(format!("lpdsvm_io_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.lpd");
        save(&tiny_model(), &path).unwrap();

        // Flip one payload byte: the checksum must catch it.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");

        // Truncation (the classic torn write) is rejected too.
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("lpdsvm_model_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.lpd");
        std::fs::write(&path, b"NOTAMODEL").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn kernel_json_roundtrip() {
        for k in [
            Kernel::gaussian(0.25),
            Kernel::Polynomial {
                gamma: 0.5,
                coef0: 1.0,
                degree: 3,
            },
            Kernel::Tanh {
                gamma: 0.1,
                coef0: -1.0,
            },
            Kernel::Linear,
        ] {
            let j = kernel_to_json(&k);
            let back = kernel_from_json(&j).unwrap();
            assert_eq!(k, back);
        }
    }
}
