//! Binary and one-versus-one multiclass model heads over a shared
//! low-rank factor.

use crate::data::sparse::SparseMatrix;
use crate::linalg::Mat;
use crate::lowrank::factor::{NativeBackend, Stage1Backend};
use crate::lowrank::LowRankFactor;
use crate::model::ModelKind;

/// One trained binary head: weights in G-space plus training diagnostics.
#[derive(Clone, Debug)]
pub struct BinaryHead {
    /// The class pair this head separates (for OVO; `(0,1)` for binary).
    pub pair: (u32, u32),
    /// Weight vector, length = factor rank. Decision value on a feature
    /// row `g` is `⟨g, w⟩`; positive ⇒ class `pair.1`.
    pub w: Vec<f32>,
    pub objective: f64,
    pub converged: bool,
    pub sv_count: usize,
    pub steps: u64,
}

/// A full trained model: factor + one or more binary heads.
pub struct MulticlassModel {
    pub factor: LowRankFactor,
    pub heads: Vec<BinaryHead>,
    pub kind: ModelKind,
}

impl MulticlassModel {
    pub fn n_classes(&self) -> usize {
        match self.kind {
            ModelKind::Binary => 2,
            ModelKind::OneVsOne { n_classes } => n_classes,
        }
    }

    /// Map new inputs into G-space using the given backend.
    pub fn features(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
    ) -> anyhow::Result<Mat> {
        self.factor.transform(x, backend, 1024)
    }

    /// Predict class labels with the native backend.
    pub fn predict(&self, x: &SparseMatrix) -> anyhow::Result<Vec<u32>> {
        self.predict_with_backend(x, &NativeBackend)
    }

    /// Predict class labels; `backend` controls how features are computed
    /// (native GEMM vs PJRT artifact).
    pub fn predict_with_backend(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
    ) -> anyhow::Result<Vec<u32>> {
        let g = self.features(x, backend)?;
        Ok(self.predict_from_features(&g))
    }

    /// Predict from precomputed G-space features (e.g. shared across folds).
    pub fn predict_from_features(&self, g: &Mat) -> Vec<u32> {
        match self.kind {
            ModelKind::Binary => {
                let head = &self.heads[0];
                g.matvec(&head.w)
                    .into_iter()
                    .map(|s| if s > 0.0 { 1 } else { 0 })
                    .collect()
            }
            ModelKind::OneVsOne { n_classes } => {
                // Batch decision values: scores = G · W_pairsᵀ (n × pairs) —
                // one dense matmul, the GPU-friendly prediction path.
                let w_mat = self.weight_matrix();
                let scores = g.matmul_nt(&w_mat);
                (0..g.rows)
                    .map(|i| {
                        let mut votes = vec![0u32; n_classes];
                        for (p, head) in self.heads.iter().enumerate() {
                            let winner = if scores.at(i, p) > 0.0 {
                                head.pair.1
                            } else {
                                head.pair.0
                            };
                            votes[winner as usize] += 1;
                        }
                        // Ties break toward the lowest class id (stable,
                        // LIBSVM-compatible).
                        let mut best = 0usize;
                        for c in 1..n_classes {
                            if votes[c] > votes[best] {
                                best = c;
                            }
                        }
                        best as u32
                    })
                    .collect()
            }
        }
    }

    /// Stack all head weights into a `pairs × rank` matrix.
    pub fn weight_matrix(&self) -> Mat {
        let rank = self.factor.rank;
        let mut m = Mat::zeros(self.heads.len(), rank);
        for (i, h) in self.heads.iter().enumerate() {
            m.row_mut(i).copy_from_slice(&h.w);
        }
        m
    }

    /// Classification error rate against ground-truth labels.
    pub fn error_rate(&self, x: &SparseMatrix, labels: &[u32]) -> anyhow::Result<f64> {
        let preds = self.predict(x)?;
        Ok(error_rate(&preds, labels))
    }
}

/// Fraction of mismatched labels.
pub fn error_rate(preds: &[u32], labels: &[u32]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| p != l)
        .count() as f64
        / preds.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rate_basic() {
        assert_eq!(error_rate(&[1, 0, 1], &[1, 1, 1]), 1.0 / 3.0);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }

    // Full-model behaviour is covered by coordinator::train tests and the
    // integration suite; unit tests here focus on the voting logic.
    #[test]
    fn ovo_voting_majority() {
        use crate::kernel::Kernel;
        // Hand-built degenerate model: rank-1 factor, 3 classes, heads with
        // fixed weights so votes are deterministic.
        let factor = LowRankFactor {
            g: Mat::from_vec(1, 1, vec![1.0]),
            landmarks: Mat::from_vec(1, 1, vec![1.0]),
            landmark_sq: vec![1.0],
            whiten: Mat::from_vec(1, 1, vec![1.0]),
            rank: 1,
            eigenvalues: vec![1.0],
            kernel: Kernel::Linear,
            landmark_idx: vec![0],
        };
        let heads = vec![
            BinaryHead {
                pair: (0, 1),
                w: vec![1.0], // positive scores → class 1
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
            BinaryHead {
                pair: (0, 2),
                w: vec![-1.0], // negative → class 0
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
            BinaryHead {
                pair: (1, 2),
                w: vec![1.0], // positive → class 2
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
        ];
        let model = MulticlassModel {
            factor,
            heads,
            kind: ModelKind::OneVsOne { n_classes: 3 },
        };
        // Feature g = [2.0]: head votes → 1, 0, 2 → tie broken by lowest id.
        let g = Mat::from_vec(1, 1, vec![2.0]);
        let pred = model.predict_from_features(&g);
        assert_eq!(pred, vec![0]);
    }
}
