//! Binary and one-versus-one multiclass model heads over a shared
//! low-rank factor.

use crate::data::sparse::SparseMatrix;
use crate::linalg::Mat;
use crate::lowrank::factor::{NativeBackend, Stage1Backend};
use crate::lowrank::LowRankFactor;
use crate::model::ModelKind;

/// One trained binary head: weights in G-space plus training diagnostics.
#[derive(Clone, Debug)]
pub struct BinaryHead {
    /// The class pair this head separates (for OVO; `(0,1)` for binary).
    pub pair: (u32, u32),
    /// Weight vector, length = factor rank. Decision value on a feature
    /// row `g` is `⟨g, w⟩`; positive ⇒ class `pair.1`.
    pub w: Vec<f32>,
    pub objective: f64,
    pub converged: bool,
    pub sv_count: usize,
    pub steps: u64,
}

/// A full trained model: factor + one or more binary heads.
pub struct MulticlassModel {
    pub factor: LowRankFactor,
    pub heads: Vec<BinaryHead>,
    pub kind: ModelKind,
}

impl MulticlassModel {
    pub fn n_classes(&self) -> usize {
        match self.kind {
            ModelKind::Binary => 2,
            ModelKind::OneVsOne { n_classes } => n_classes,
        }
    }

    /// Map new inputs into G-space using the given backend.
    pub fn features(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
    ) -> anyhow::Result<Mat> {
        self.factor.transform(x, backend, 1024)
    }

    /// Predict class labels with the native backend.
    pub fn predict(&self, x: &SparseMatrix) -> anyhow::Result<Vec<u32>> {
        self.predict_with_backend(x, &NativeBackend::default())
    }

    /// Predict class labels; `backend` controls how features are computed
    /// (native GEMM vs PJRT artifact).
    pub fn predict_with_backend(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
    ) -> anyhow::Result<Vec<u32>> {
        let g = self.features(x, backend)?;
        Ok(self.predict_from_features(&g))
    }

    /// Predict from precomputed G-space features (e.g. shared across
    /// folds). Rebuilds the stacked weight matrix per call; hot paths that
    /// score the same model repeatedly (the serve registry) should cache
    /// [`MulticlassModel::weight_matrix`] once and use
    /// [`MulticlassModel::predict_with_weights`].
    pub fn predict_from_features(&self, g: &Mat) -> Vec<u32> {
        match self.kind {
            ModelKind::Binary => {
                let head = &self.heads[0];
                g.matvec(&head.w)
                    .into_iter()
                    .map(|s| if s > 0.0 { 1 } else { 0 })
                    .collect()
            }
            ModelKind::OneVsOne { .. } => self.predict_with_weights(g, &self.weight_matrix()),
        }
    }

    /// Predict from precomputed features *and* a precomputed stacked
    /// weight matrix (see [`MulticlassModel::weight_matrix`]) — the serve
    /// hot path, where the registry builds the stack once at insert time
    /// instead of once per batch.
    pub fn predict_with_weights(&self, g: &Mat, w_mat: &Mat) -> Vec<u32> {
        assert!(
            w_mat.rows == self.heads.len() && w_mat.cols == self.factor.rank,
            "weight matrix is {}x{} but the model has {} heads of rank {}",
            w_mat.rows,
            w_mat.cols,
            self.heads.len(),
            self.factor.rank
        );
        match self.kind {
            ModelKind::Binary => {
                g.matvec(&self.heads[0].w)
                    .into_iter()
                    .map(|s| if s > 0.0 { 1 } else { 0 })
                    .collect()
            }
            ModelKind::OneVsOne { n_classes } => {
                // Batch decision values: scores = G · W_pairsᵀ (n × pairs) —
                // one dense matmul, the GPU-friendly prediction path.
                let scores = g.matmul_nt(w_mat);
                (0..g.rows)
                    .map(|i| {
                        let mut votes = vec![0u32; n_classes];
                        for (p, head) in self.heads.iter().enumerate() {
                            let winner = if scores.at(i, p) > 0.0 {
                                head.pair.1
                            } else {
                                head.pair.0
                            };
                            votes[winner as usize] += 1;
                        }
                        // Ties break toward the lowest class id (stable,
                        // LIBSVM-compatible).
                        let mut best = 0usize;
                        for c in 1..n_classes {
                            if votes[c] > votes[best] {
                                best = c;
                            }
                        }
                        best as u32
                    })
                    .collect()
            }
        }
    }

    /// Stack all head weights into a `pairs × rank` matrix.
    pub fn weight_matrix(&self) -> Mat {
        let rank = self.factor.rank;
        let mut m = Mat::zeros(self.heads.len(), rank);
        for (i, h) in self.heads.iter().enumerate() {
            m.row_mut(i).copy_from_slice(&h.w);
        }
        m
    }

    /// Classification error rate against ground-truth labels. Errors on
    /// zero rows (an error rate over nothing is meaningless, and silently
    /// returning 0.0 would read as "perfect") and on a row/label count
    /// mismatch.
    pub fn error_rate(&self, x: &SparseMatrix, labels: &[u32]) -> anyhow::Result<f64> {
        anyhow::ensure!(x.rows > 0, "error_rate: empty input (0 rows)");
        anyhow::ensure!(
            x.rows == labels.len(),
            "error_rate: {} rows but {} labels",
            x.rows,
            labels.len()
        );
        let preds = self.predict(x)?;
        Ok(error_rate(&preds, labels))
    }
}

/// Fraction of mismatched labels. Empty input is defined as error 0.0
/// (no divide-by-zero NaN); callers that need "no data" surfaced as a
/// failure should go through [`MulticlassModel::error_rate`].
pub fn error_rate(preds: &[u32], labels: &[u32]) -> f64 {
    assert_eq!(preds.len(), labels.len(), "prediction/label count mismatch");
    if preds.is_empty() {
        return 0.0;
    }
    preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| p != l)
        .count() as f64
        / preds.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rate_basic() {
        assert_eq!(error_rate(&[1, 0, 1], &[1, 1, 1]), 1.0 / 3.0);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }

    // Full-model behaviour is covered by coordinator::train tests and the
    // integration suite; unit tests here focus on the voting logic.
    #[test]
    fn ovo_voting_majority() {
        use crate::kernel::Kernel;
        // Hand-built degenerate model: rank-1 factor, 3 classes, heads with
        // fixed weights so votes are deterministic.
        let factor = LowRankFactor {
            g: Mat::from_vec(1, 1, vec![1.0]),
            landmarks: Mat::from_vec(1, 1, vec![1.0]),
            landmark_sq: vec![1.0],
            whiten: Mat::from_vec(1, 1, vec![1.0]),
            rank: 1,
            eigenvalues: vec![1.0],
            kernel: Kernel::Linear,
            landmark_idx: vec![0],
        };
        let heads = vec![
            BinaryHead {
                pair: (0, 1),
                w: vec![1.0], // positive scores → class 1
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
            BinaryHead {
                pair: (0, 2),
                w: vec![-1.0], // negative → class 0
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
            BinaryHead {
                pair: (1, 2),
                w: vec![1.0], // positive → class 2
                objective: 0.0,
                converged: true,
                sv_count: 0,
                steps: 0,
            },
        ];
        let model = MulticlassModel {
            factor,
            heads,
            kind: ModelKind::OneVsOne { n_classes: 3 },
        };
        // Feature g = [2.0]: head votes → 1, 0, 2 → tie broken by lowest id.
        let g = Mat::from_vec(1, 1, vec![2.0]);
        let pred = model.predict_from_features(&g);
        assert_eq!(pred, vec![0]);
    }

    /// Degenerate rank-1 factor for hand-built voting tests.
    fn unit_factor() -> LowRankFactor {
        use crate::kernel::Kernel;
        LowRankFactor {
            g: Mat::from_vec(1, 1, vec![1.0]),
            landmarks: Mat::from_vec(1, 1, vec![1.0]),
            landmark_sq: vec![1.0],
            whiten: Mat::from_vec(1, 1, vec![1.0]),
            rank: 1,
            eigenvalues: vec![1.0],
            kernel: Kernel::Linear,
            landmark_idx: vec![0],
        }
    }

    fn head(pair: (u32, u32), w: f32) -> BinaryHead {
        BinaryHead {
            pair,
            w: vec![w],
            objective: 0.0,
            converged: true,
            sv_count: 0,
            steps: 0,
        }
    }

    #[test]
    fn binary_sign_convention_positive_is_pair_1() {
        // Decision value ⟨g, w⟩ > 0 must yield class pair.1 (= 1 for
        // binary); ≤ 0 (including exactly 0) yields pair.0 (= 0).
        let model = MulticlassModel {
            factor: unit_factor(),
            heads: vec![head((0, 1), 1.0)],
            kind: ModelKind::Binary,
        };
        let g = Mat::from_vec(3, 1, vec![2.5, -2.5, 0.0]);
        assert_eq!(model.predict_from_features(&g), vec![1, 0, 0]);
    }

    #[test]
    fn ovo_head_sign_convention_positive_is_pair_1() {
        // One 3-class model where a single feature sign decides every
        // head: positive score → pair.1 wins that head's vote.
        let model = MulticlassModel {
            factor: unit_factor(),
            heads: vec![head((0, 1), 1.0), head((0, 2), 1.0), head((1, 2), 1.0)],
            kind: ModelKind::OneVsOne { n_classes: 3 },
        };
        // g = +1: votes (0,1)→1, (0,2)→2, (1,2)→2 ⇒ class 2 on 2 votes.
        assert_eq!(
            model.predict_from_features(&Mat::from_vec(1, 1, vec![1.0])),
            vec![2]
        );
        // g = −1: every head votes pair.0 ⇒ class 0 on 2 votes.
        assert_eq!(
            model.predict_from_features(&Mat::from_vec(1, 1, vec![-1.0])),
            vec![0]
        );
    }

    #[test]
    fn ovo_equal_votes_tie_breaks_to_lowest_class_id() {
        // 4 classes, weights arranged so classes 1 and 2 each collect two
        // votes (classes 0 and 3 one each): the LIBSVM-compatible rule
        // must deterministically pick class 1, the lowest tied id.
        // Per-head votes at g = [1.0]: 1, 2, 0, 1, 3, 2 ⇒ tally
        // [1, 2, 2, 1] over classes 0..4.
        let model = MulticlassModel {
            factor: unit_factor(),
            heads: vec![
                head((0, 1), 1.0),  // +1 → votes 1
                head((0, 2), 1.0),  // +1 → votes 2
                head((0, 3), -1.0), // −1 → votes 0
                head((1, 2), -1.0), // −1 → votes 1
                head((1, 3), 1.0),  // +1 → votes 3
                head((2, 3), -1.0), // −1 → votes 2
            ],
            kind: ModelKind::OneVsOne { n_classes: 4 },
        };
        let pred = model.predict_from_features(&Mat::from_vec(1, 1, vec![1.0]));
        assert_eq!(pred, vec![1], "tie between classes 1 and 2 breaks low");
        // Scaling the feature must not change the outcome (tie-break is a
        // function of votes, not margins).
        let pred2 = model.predict_from_features(&Mat::from_vec(1, 1, vec![42.0]));
        assert_eq!(pred2, vec![1]);
    }

    #[test]
    fn model_error_rate_rejects_empty_and_mismatched_inputs() {
        let model = MulticlassModel {
            factor: unit_factor(),
            heads: vec![head((0, 1), 1.0)],
            kind: ModelKind::Binary,
        };
        let empty = SparseMatrix::empty(1);
        let err = model.error_rate(&empty, &[]).unwrap_err();
        assert!(format!("{err}").contains("empty"), "got: {err}");
        let one = SparseMatrix::from_rows(1, &[vec![(0, 1.0)]]);
        let err = model.error_rate(&one, &[0, 1]).unwrap_err();
        assert!(format!("{err}").contains("labels"), "got: {err}");
        // Well-formed input still works.
        assert!(model.error_rate(&one, &[1]).is_ok());
    }
}
