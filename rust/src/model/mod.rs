//! Trained model representations and persistence.
//!
//! An LPD-SVM model is the stage-1 factor metadata (landmarks + whitening
//! map + kernel) plus linear weights in G-space: one weight vector for a
//! binary problem, one per class pair for one-versus-one multiclass.
//! Prediction is `G_new = K(X_new, L)·W` followed by a dense matmul and
//! (for multiclass) pairwise voting — the batch-friendly step the paper
//! runs on the GPU.
//!
//! Invariants: OVO vote ties break toward the lower class id
//! (deterministic predictions); persistence round-trips exactly (save →
//! load reproduces every weight bit); prediction through any backend
//! agrees with the native serial path.

pub mod io;
pub mod multiclass;

pub use multiclass::{BinaryHead, MulticlassModel};

/// Discriminates the model head.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Binary,
    OneVsOne { n_classes: usize },
}
