//! CRC-32 (IEEE 802.3, polynomial `0xEDB88320`) for file integrity
//! footers.
//!
//! The offline registry has no `crc32fast`/`crc` crate, so we ship the
//! classic byte-at-a-time table implementation. It is not a hot path:
//! checksums are computed once per model save/load and once per training
//! checkpoint, over buffers that are tiny next to the GEMM traffic. What
//! matters is that the value is stable, standard (matches `cksum -o3`,
//! zlib, PNG, gzip), and byte-exact across platforms — a checkpoint
//! written on one machine must verify on another.

/// Streaming CRC-32 state. Feed bytes with [`Crc32::update`], read the
/// final value with [`Crc32::finish`].
pub struct Crc32 {
    state: u32,
}

/// The 256-entry lookup table for the reflected IEEE polynomial,
/// computed at compile time so there is no lazy-init synchronization.
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb `bytes` into the running checksum.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The final CRC-32 value of everything absorbed so far.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"checkpointed solver state, many bytes of it";
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn single_bit_flip_changes_value() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        data[40] ^= 0x01;
        assert_ne!(crc32(&data), base);
    }
}
