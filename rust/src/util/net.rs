//! Readiness polling without a libc crate: `epoll` (with a `poll(2)`
//! fallback) and a self-wake pipe, declared directly against the platform
//! C library that `std` already links.
//!
//! This is the substrate of the evented HTTP front-end
//! (`crate::serve::evented`): a [`Poller`] multiplexes thousands of
//! nonblocking sockets onto one thread, and a [`WakePipe`] lets scoring
//! workers nudge that thread from the outside without touching a socket.
//! Everything here is Linux-only (the module is gated in `util/mod.rs`);
//! the rest of the crate compiles without it and the CLI rejects
//! `--io-model evented` on other platforms.
//!
//! Why two pollers: `epoll` is the scalable production path (O(ready)
//! wakeups), while [`PollPoller`] drives the identical event loop through
//! portable `poll(2)` — a differential double-check of the readiness
//! plumbing (`LPDSVM_POLLER=poll` selects it at runtime) and the fallback
//! the tentpole design calls for.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

// Linux ABI constants (asm-generic values; x86_64 and aarch64 agree).
const EPOLL_CLOEXEC: i32 = 0o2000000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;
const O_NONBLOCK: i32 = 0o4000;
const O_CLOEXEC: i32 = 0o2000000;

/// `struct epoll_event`. The kernel ABI packs it on x86_64 (12 bytes,
/// align 1) and leaves natural alignment elsewhere; mirror glibc's
/// `__EPOLL_PACKED` split or `epoll_wait` would scribble past every
/// other entry of the event array.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

/// `struct epoll_event` with the natural (non-x86_64) layout.
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

/// `struct pollfd` — identical layout on every Linux target.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    fn pipe2(fds: *mut i32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

/// Which directions a registered fd wants readiness for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
    /// Error/hangup only — a parked connection (e.g. one waiting on the
    /// engine) that should still learn about a peer disappearing.
    pub const NONE: Interest = Interest { readable: false, writable: false };
}

/// One readiness report from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup: the fd needs attention regardless of interest.
    pub error: bool,
}

fn last_err() -> io::Error {
    io::Error::last_os_error()
}

/// `Duration` → poll/epoll millisecond timeout. `None` blocks forever;
/// sub-millisecond waits round up so a short deadline cannot spin.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    }
}

/// Readiness multiplexer: epoll by default, `poll(2)` when constructed
/// via [`Poller::new_poll`] (or `LPDSVM_POLLER=poll`). Both variants
/// expose the same level-triggered register/modify/deregister/wait
/// surface, so the event loop above is oblivious to the backend.
pub enum Poller {
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    /// Backend chosen by `LPDSVM_POLLER` (`epoll` default, `poll` the
    /// portable fallback).
    pub fn new() -> io::Result<Poller> {
        match std::env::var("LPDSVM_POLLER").as_deref() {
            Ok("poll") => Ok(Self::new_poll()),
            _ => Ok(Poller::Epoll(EpollPoller::new()?)),
        }
    }

    pub fn new_poll() -> Poller {
        Poller::Poll(PollPoller::new())
    }

    /// Human-readable backend name (for startup logs).
    pub fn backend(&self) -> &'static str {
        match self {
            Poller::Epoll(_) => "epoll",
            Poller::Poll(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_ADD, fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_MOD, fd, token, interest),
            Poller::Poll(p) => p.modify(fd, interest),
        }
    }

    /// Forget `fd`. Call before the fd is closed: epoll drops closed fds
    /// on its own, but the poll fallback would keep seeing POLLNVAL.
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            Poller::Epoll(p) => p.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE),
            Poller::Poll(p) => p.deregister(fd),
        }
    }

    /// Number of registered fds (the leak-check surface for tests).
    pub fn registered(&self) -> usize {
        match self {
            Poller::Epoll(p) => p.registered,
            Poller::Poll(p) => p.fds.len(),
        }
    }

    /// Block up to `timeout` for readiness; `events` is cleared and
    /// refilled. A signal (EINTR) returns an empty set rather than an
    /// error so callers just re-loop.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        match self {
            Poller::Epoll(p) => p.wait(events, timeout),
            Poller::Poll(p) => p.wait(events, timeout),
        }
    }
}

/// The epoll backend: one epoll instance, fds tagged with u64 tokens.
pub struct EpollPoller {
    epfd: RawFd,
    /// Scratch buffer reused across waits.
    buf: Vec<EpollEvent>,
    registered: usize,
}

impl EpollPoller {
    fn new() -> io::Result<EpollPoller> {
        // SAFETY: epoll_create1 takes a flags word and returns a new fd
        // or -1; no pointers are involved.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(last_err());
        }
        Ok(EpollPoller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024], registered: 0 })
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest_bits(interest), data: token };
        // SAFETY: `ev` is a valid epoll_event for the duration of the
        // call (the kernel copies it); for EPOLL_CTL_DEL the pointer is
        // ignored on any kernel ≥ 2.6.9 but still valid here.
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(last_err());
        }
        match op {
            EPOLL_CTL_ADD => self.registered += 1,
            EPOLL_CTL_DEL => self.registered = self.registered.saturating_sub(1),
            _ => {}
        }
        Ok(())
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        // SAFETY: `buf` is a live, writable array of epoll_event and the
        // length passed never exceeds its capacity.
        let n = unsafe {
            epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms(timeout))
        };
        if n < 0 {
            let e = last_err();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for i in 0..n as usize {
            // Copy out of the (possibly packed) struct before using.
            let bits = self.buf[i].events;
            let token = self.buf[i].data;
            events.push(Event {
                token,
                readable: bits & EPOLLIN != 0,
                writable: bits & EPOLLOUT != 0,
                error: bits & (EPOLLERR | EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

impl Drop for EpollPoller {
    fn drop(&mut self) {
        // SAFETY: epfd is a valid fd owned by this struct and closed
        // exactly once.
        unsafe { close(self.epfd) };
    }
}

fn interest_bits(interest: Interest) -> u32 {
    let mut bits = 0;
    if interest.readable {
        bits |= EPOLLIN;
    }
    if interest.writable {
        bits |= EPOLLOUT;
    }
    bits
}

/// The `poll(2)` fallback: a flat pollfd array re-submitted every wait.
/// O(n) per wakeup, which is fine for its role as a differential check
/// and portability fallback.
pub struct PollPoller {
    fds: Vec<PollFd>,
    tokens: Vec<u64>,
}

impl PollPoller {
    fn new() -> PollPoller {
        PollPoller { fds: Vec::new(), tokens: Vec::new() }
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.fds.iter().any(|p| p.fd == fd) {
            return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
        }
        self.fds.push(PollFd { fd, events: poll_bits(interest), revents: 0 });
        self.tokens.push(token);
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, interest: Interest) -> io::Result<()> {
        match self.fds.iter_mut().find(|p| p.fd == fd) {
            Some(p) => {
                p.events = poll_bits(interest);
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
        }
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self.fds.iter().position(|p| p.fd == fd) {
            Some(i) => {
                self.fds.swap_remove(i);
                self.tokens.swap_remove(i);
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
        }
    }

    fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        // SAFETY: the pollfd array is live and writable for the duration
        // of the call and nfds matches its length.
        let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as u64, timeout_ms(timeout)) };
        if n < 0 {
            let e = last_err();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (i, p) in self.fds.iter().enumerate() {
            let bits = p.revents;
            if bits == 0 {
                continue;
            }
            events.push(Event {
                token: self.tokens[i],
                readable: bits & POLLIN != 0,
                writable: bits & POLLOUT != 0,
                error: bits & (POLLERR | POLLHUP | POLLNVAL) != 0,
            });
        }
        Ok(())
    }
}

fn poll_bits(interest: Interest) -> i16 {
    let mut bits = 0;
    if interest.readable {
        bits |= POLLIN;
    }
    if interest.writable {
        bits |= POLLOUT;
    }
    bits
}

/// Self-wake channel for the event loop: any thread calls
/// [`WakePipe::wake`], the loop sees the read end become readable and
/// [`WakePipe::drain`]s it. Both ends are nonblocking, so a wake can
/// never stall the waker (a full pipe already guarantees a pending
/// wakeup) and a drain can never stall the loop.
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0i32; 2];
        // SAFETY: pipe2 writes exactly two fds into the array provided.
        let rc = unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) };
        if rc < 0 {
            return Err(last_err());
        }
        Ok(WakePipe { read_fd: fds[0], write_fd: fds[1] })
    }

    /// The end to register with the [`Poller`].
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Nudge the loop. Best-effort by design: EAGAIN means the pipe is
    /// already full of unconsumed wakeups, which is itself a wakeup.
    pub fn wake(&self) {
        let byte = [1u8];
        // SAFETY: writes one byte from a live buffer to an fd this
        // struct owns; the fd is nonblocking so the call cannot stall.
        unsafe { write(self.write_fd, byte.as_ptr(), 1) };
    }

    /// Consume every pending wakeup byte (called by the loop once per
    /// readiness report).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reads into a live, writable buffer from an fd this
            // struct owns; nonblocking, so it returns -1/EAGAIN when dry.
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                return;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // SAFETY: both fds are owned by this struct and closed exactly
        // once each.
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_wake_cycle(mut poller: Poller) {
        let pipe = WakePipe::new().expect("pipe");
        poller.register(pipe.read_fd(), 7, Interest::READ).expect("register");
        assert_eq!(poller.registered(), 1);
        let mut events = Vec::new();

        // No wake yet: a short wait times out empty.
        poller.wait(&mut events, Some(Duration::from_millis(5))).expect("wait");
        assert!(events.is_empty(), "spurious readiness before wake");

        // Wakes from another thread surface as readability on the token.
        pipe.wake();
        pipe.wake();
        poller.wait(&mut events, Some(Duration::from_secs(5))).expect("wait");
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Drained pipe goes quiet again (level-triggered: undrained
        // bytes would re-report forever).
        pipe.drain();
        poller.wait(&mut events, Some(Duration::from_millis(5))).expect("wait");
        assert!(events.is_empty(), "drain did not clear readiness");

        poller.deregister(pipe.read_fd()).expect("deregister");
        assert_eq!(poller.registered(), 0);
    }

    #[test]
    fn epoll_wake_cycle() {
        check_wake_cycle(Poller::Epoll(EpollPoller::new().expect("epoll")));
    }

    #[test]
    fn poll_fallback_wake_cycle() {
        check_wake_cycle(Poller::new_poll());
    }

    #[test]
    fn modify_switches_interest() {
        for mut poller in [
            Poller::Epoll(EpollPoller::new().expect("epoll")),
            Poller::new_poll(),
        ] {
            let pipe = WakePipe::new().expect("pipe");
            pipe.wake();
            let mut events = Vec::new();
            // Registered with no interest: the pending byte is invisible.
            poller.register(pipe.read_fd(), 1, Interest::NONE).expect("register");
            poller.wait(&mut events, Some(Duration::from_millis(5))).expect("wait");
            assert!(events.iter().all(|e| !e.readable), "interest NONE reported readable");
            // Flip to READ: the same byte becomes visible immediately.
            poller.modify(pipe.read_fd(), 1, Interest::READ).expect("modify");
            poller.wait(&mut events, Some(Duration::from_secs(5))).expect("wait");
            assert!(events.iter().any(|e| e.token == 1 && e.readable));
            poller.deregister(pipe.read_fd()).expect("deregister");
        }
    }
}
