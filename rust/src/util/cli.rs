//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` text. Each subcommand in `main.rs` declares an
//! `ArgSpec` list; parsing returns a `Parsed` map with typed getters.

use std::collections::BTreeMap;

/// Declaration of one accepted option.
#[derive(Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

impl ArgSpec {
    pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        }
    }
    pub fn req(name: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        }
    }
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        }
    }
}

/// Parsed argument values.
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("unknown or missing option --{name}"))
    }
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }
    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }
    pub fn u64(&self, name: &str) -> anyhow::Result<u64> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// Render help text for a subcommand.
pub fn help(cmd: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut out = format!("{about}\n\nUsage: lpdsvm {cmd} [options]\n\nOptions:\n");
    for s in specs {
        let head = if s.is_flag {
            format!("  --{}", s.name)
        } else {
            format!("  --{} <value>", s.name)
        };
        let dflt = match s.default {
            Some(d) if !s.is_flag => format!(" [default: {d}]"),
            _ if !s.is_flag => " [required]".to_string(),
            _ => String::new(),
        };
        out.push_str(&format!("{head:<28} {}{dflt}\n", s.help));
    }
    out.push_str("  --help                     show this message\n");
    out
}

/// Parse `args` (excluding program name and subcommand) against `specs`.
pub fn parse(cmd: &str, about: &str, specs: &[ArgSpec], args: &[String]) -> anyhow::Result<Parsed> {
    let mut values = BTreeMap::new();
    let mut flags = BTreeMap::new();
    let mut positional = Vec::new();
    for s in specs {
        if let Some(d) = s.default {
            values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "--help" || a == "-h" {
            // Requested output, not a diagnostic: stdout, not the logger.
            println!("{}", help(cmd, about, specs));
            std::process::exit(0);
        }
        if let Some(rest) = a.strip_prefix("--") {
            let (key, inline_val) = match rest.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (rest, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{key} (see --help)"))?;
            if spec.is_flag {
                if inline_val.is_some() {
                    anyhow::bail!("--{key} is a flag and takes no value");
                }
                flags.insert(key.to_string(), true);
            } else {
                let v = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?
                            .clone()
                    }
                };
                values.insert(key.to_string(), v);
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    for s in specs {
        if !s.is_flag && !values.contains_key(s.name) {
            anyhow::bail!("missing required option --{} (see --help)", s.name);
        }
    }
    Ok(Parsed {
        values,
        flags,
        positional,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("budget", "512", "budget size B"),
            ArgSpec::req("data", "dataset path"),
            ArgSpec::flag("no-shrinking", "disable shrinking"),
        ]
    }

    fn to_args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let p = parse("train", "", &specs(), &to_args(&["--data", "x.svm"])).unwrap();
        assert_eq!(p.str("budget"), "512");
        assert_eq!(p.str("data"), "x.svm");
        assert!(!p.flag("no-shrinking"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = parse(
            "train",
            "",
            &specs(),
            &to_args(&["--data=x", "--budget=64", "--no-shrinking"]),
        )
        .unwrap();
        assert_eq!(p.usize("budget").unwrap(), 64);
        assert!(p.flag("no-shrinking"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse("train", "", &specs(), &to_args(&["--budget", "8"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse("train", "", &specs(), &to_args(&["--data", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = parse("train", "", &specs(), &to_args(&["--data", "x", "extra"])).unwrap();
        assert_eq!(p.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parse(
            "train",
            "",
            &specs(),
            &to_args(&["--data", "x", "--no-shrinking=1"])
        )
        .is_err());
    }
}
