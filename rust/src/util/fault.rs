//! Deterministic fault injection behind named fault points.
//!
//! Robustness claims in this repo — "a crash between temp-write and
//! rename never corrupts the model", "the serve engine returns to full
//! strength after a panic storm" — are only claims until a fault actually
//! fires at the interesting instruction. This module makes that firing
//! deterministic and scriptable: production code declares *named fault
//! points* at its crash-relevant boundaries, and a schedule (set
//! programmatically or via the `LPDSVM_FAULTS` environment variable)
//! decides which points misbehave, how, and on which hit.
//!
//! ```no_run
//! // In production code, at the boundary worth crashing at:
//! lpdsvm::util::fault::point("ckpt.after_tmp_write")?;
//! ```
//!
//! With no schedule armed, [`point`] is a single relaxed atomic load and
//! an immediate `Ok(())` — the same zero-cost-when-off discipline as the
//! observability spans, so fault points are safe to leave in hot-ish
//! paths like checkpoint writes and batch dispatch.
//!
//! # Schedule grammar (`LPDSVM_FAULTS`)
//!
//! A schedule is `;`- or `,`-separated clauses of the form
//!
//! ```text
//! <point>=<action>[@<start>][x<count>]
//! ```
//!
//! * `<action>` — `error` (the point returns [`FaultError`], which
//!   propagates through the surrounding `Result` plumbing), `panic`
//!   (the point panics, exercising unwind/supervision paths), `abort`
//!   (immediate `std::process::abort()`, the honest stand-in for
//!   SIGKILL / power loss), or `delay:<ms>` (sleep, for racing timeouts).
//! * `@<start>` — first hit that triggers, 1-based (default 1: the very
//!   first execution of the point).
//! * `x<count>` — how many consecutive hits trigger (default 1;
//!   `x*` = every hit from `<start>` on).
//!
//! `LPDSVM_FAULTS='ckpt.after_tmp_write=abort@2'` aborts the process the
//! second time a checkpoint reaches the post-temp-write boundary;
//! `serve.batch=panic x3` panics the first three scored batches —
//! exactly the K consecutive panics that trip the circuit breaker.
//!
//! Hit counting is per-point and process-global, guarded by one mutex on
//! the armed path — deterministic even when many workers pass the same
//! point concurrently (the *set* of triggered hits is fixed, whichever
//! thread draws them).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Central registry of every fault point the codebase declares.
///
/// The `fault-point-registry` lint rule checks each literal
/// `fault::point("...")` site against this list, so a drill schedule can
/// never target a typo'd name that silently no-ops — and this constant
/// doubles as the authoritative inventory for the fault-point table in
/// `docs/ARCHITECTURE.md`. Names are `<subsystem>.<boundary>`.
pub const FAULT_POINTS: &[&str] = &[
    "ckpt.after_tmp_write",
    "model.save.after_tmp_write",
    "data.load",
    "serve.worker",
    "serve.batch",
    "fsio.test.write",
];

/// What a triggered fault point does.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Return [`FaultError`] from the point.
    Error,
    /// Panic at the point (unwinds into whatever supervision surrounds it).
    Panic,
    /// `std::process::abort()` — no unwinding, no destructors; the
    /// in-process equivalent of SIGKILL for crash-recovery drills.
    Abort,
    /// Sleep this long, then continue normally.
    Delay(Duration),
}

/// One armed fault point: the action plus its trigger window.
#[derive(Clone, Debug)]
struct FaultRule {
    action: FaultAction,
    /// 1-based hit number of the first trigger.
    start: u64,
    /// Number of triggering hits; `None` = unlimited.
    count: Option<u64>,
    /// Executions of this point observed so far.
    hits: u64,
}

/// The error returned by a `error`-action fault point. Implements
/// `std::error::Error`, so it rides the existing `anyhow`/`?` plumbing of
/// whatever I/O path it interrupts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultError {
    /// Name of the fault point that fired.
    pub point: String,
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault at point '{}'", self.point)
    }
}

impl std::error::Error for FaultError {}

/// Fast-path switch: `false` means no schedule is armed and [`point`]
/// returns after one relaxed load.
static ARMED: AtomicBool = AtomicBool::new(false);

/// The armed schedule. `None` when disarmed; the mutex also serializes
/// hit counting, which keeps trigger windows exact under concurrency.
static SCHEDULE: Mutex<Option<HashMap<String, FaultRule>>> = Mutex::new(None);

/// Serializes tests that arm process-global schedules. Poison-tolerant:
/// a panicking test (several fault tests panic on purpose) must not
/// poison the whole suite.
static TEST_GATE: Mutex<()> = Mutex::new(());

fn lock_schedule() -> MutexGuard<'static, Option<HashMap<String, FaultRule>>> {
    // lock_recover: a panic while holding the lock (FaultAction::Panic
    // drops the guard first, but a user panic inside `set_schedule`'s
    // parser could not) should not disable fault injection for the rest
    // of the process; the single `Option<HashMap>` is always valid.
    crate::util::sync::lock_recover(&SCHEDULE)
}

/// Declare a fault point. Returns `Ok(())` (after one atomic load) unless
/// a schedule targets `name` and its trigger window covers this hit.
///
/// An `error` trigger returns `Err(FaultError)`; `panic`/`abort`/`delay`
/// act before returning. Callers on `Result` paths write
/// `fault::point("...")?;`, infallible callers (e.g. worker loops that
/// route errors themselves) match on the result.
#[inline]
pub fn point(name: &str) -> Result<(), FaultError> {
    // Relaxed: a pure on/off gate with no associated data to order —
    // arming publishes the schedule through the SCHEDULE mutex, and a
    // stale `false` just means the point stays a no-op one call longer.
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    point_slow(name)
}

#[cold]
fn point_slow(name: &str) -> Result<(), FaultError> {
    let action = {
        let mut guard = lock_schedule();
        let Some(schedule) = guard.as_mut() else { return Ok(()) };
        let Some(rule) = schedule.get_mut(name) else { return Ok(()) };
        rule.hits += 1;
        let in_window = rule.hits >= rule.start
            && match rule.count {
                None => true,
                Some(c) => rule.hits < rule.start + c,
            };
        if !in_window {
            return Ok(());
        }
        rule.action.clone()
        // Guard drops here: panic/abort/delay must not hold the lock.
    };
    match action {
        FaultAction::Error => Err(FaultError { point: name.to_string() }),
        FaultAction::Panic => panic!("injected fault at point '{name}'"),
        FaultAction::Abort => {
            // Leave a trace for the human watching the drill; abort()
            // itself says nothing.
            eprintln!("lpdsvm: injected abort at fault point '{name}'");
            std::process::abort();
        }
        FaultAction::Delay(d) => {
            std::thread::sleep(d);
            Ok(())
        }
    }
}

/// Arm a schedule from its textual form (see the module docs for the
/// grammar). Replaces any previously armed schedule; an empty spec
/// disarms, same as [`clear`].
pub fn set_schedule(spec: &str) -> anyhow::Result<()> {
    let mut map = HashMap::new();
    for clause in spec.split([';', ',']) {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (name, rule) = parse_clause(clause)?;
        map.insert(name, rule);
    }
    let mut guard = lock_schedule();
    if map.is_empty() {
        *guard = None;
        ARMED.store(false, Ordering::Release);
    } else {
        *guard = Some(map);
        ARMED.store(true, Ordering::Release);
    }
    Ok(())
}

fn parse_clause(clause: &str) -> anyhow::Result<(String, FaultRule)> {
    let (name, mut spec) = clause
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("fault clause '{clause}' is not <point>=<action>"))?;
    let name = name.trim();
    anyhow::ensure!(!name.is_empty(), "fault clause '{clause}' has an empty point name");
    spec = spec.trim();

    // Peel the trailing modifiers: ...x<count> then ...@<start>.
    let mut count = Some(1u64);
    if let Some((rest, c)) = spec.rsplit_once('x') {
        // Only treat it as a count suffix if what follows parses — the
        // action words themselves contain no 'x', so this is unambiguous.
        let c = c.trim();
        if c == "*" {
            count = None;
            spec = rest.trim_end();
        } else if let Ok(n) = c.parse::<u64>() {
            anyhow::ensure!(n >= 1, "fault clause '{clause}': count must be >= 1");
            count = Some(n);
            spec = rest.trim_end();
        }
    }
    let mut start = 1u64;
    if let Some((rest, s)) = spec.rsplit_once('@') {
        let n: u64 = s
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("fault clause '{clause}': bad @start '{s}'"))?;
        anyhow::ensure!(n >= 1, "fault clause '{clause}': @start is 1-based");
        start = n;
        spec = rest.trim_end();
    }

    let action = match spec {
        "error" => FaultAction::Error,
        "panic" => FaultAction::Panic,
        "abort" => FaultAction::Abort,
        _ => {
            if let Some(ms) = spec.strip_prefix("delay:") {
                let ms: u64 = ms.trim().parse().map_err(|_| {
                    anyhow::anyhow!("fault clause '{clause}': bad delay '{ms}' (want delay:<ms>)")
                })?;
                FaultAction::Delay(Duration::from_millis(ms))
            } else {
                anyhow::bail!(
                    "fault clause '{clause}': unknown action '{spec}' \
                     (error | panic | abort | delay:<ms>)"
                );
            }
        }
    };
    Ok((name.to_string(), FaultRule { action, start, count, hits: 0 }))
}

/// Disarm all fault points.
pub fn clear() {
    let mut guard = lock_schedule();
    *guard = None;
    ARMED.store(false, Ordering::Release);
}

/// Arm from `LPDSVM_FAULTS` if it is set and non-empty. Called once at
/// process start by the CLI; library users call [`set_schedule`] directly.
pub fn init_from_env() -> anyhow::Result<()> {
    match std::env::var("LPDSVM_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => set_schedule(&spec)
            .map_err(|e| anyhow::anyhow!("LPDSVM_FAULTS: {e}")),
        _ => Ok(()),
    }
}

/// How many times the point `name` has executed under the current
/// schedule (0 if unscheduled). Drill assertions use this to prove a
/// fault point actually ran.
pub fn hits(name: &str) -> u64 {
    let guard = lock_schedule();
    guard
        .as_ref()
        .and_then(|m| m.get(name))
        .map(|r| r.hits)
        .unwrap_or(0)
}

/// Serialize tests that arm global schedules: the returned guard holds an
/// exclusive lock released on drop. Poison-tolerant, because fault tests
/// panic on purpose.
pub fn test_lock() -> MutexGuard<'static, ()> {
    // lock_recover: the gate guards no data, so poisoning by a
    // deliberately panicking fault test carries no information.
    crate::util::sync::lock_recover(&TEST_GATE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_points_are_noops() {
        let _gate = test_lock();
        clear();
        for _ in 0..100 {
            assert!(point("any.name").is_ok());
        }
    }

    #[test]
    fn error_triggers_in_window_only() {
        let _gate = test_lock();
        set_schedule("io.write=error@3x2").unwrap();
        assert!(point("io.write").is_ok()); // hit 1
        assert!(point("io.write").is_ok()); // hit 2
        assert!(point("io.write").is_err()); // hit 3: window [3,4]
        assert!(point("io.write").is_err()); // hit 4
        assert!(point("io.write").is_ok()); // hit 5: past the window
        assert_eq!(hits("io.write"), 5);
        clear();
    }

    #[test]
    fn unlimited_count_triggers_forever() {
        let _gate = test_lock();
        set_schedule("p=error x*").unwrap();
        for _ in 0..10 {
            assert!(point("p").is_err());
        }
        clear();
    }

    #[test]
    fn unrelated_points_unaffected() {
        let _gate = test_lock();
        set_schedule("a=error").unwrap();
        assert!(point("b").is_ok());
        assert!(point("a").is_err());
        assert!(point("a").is_ok()); // count defaults to 1
        clear();
    }

    #[test]
    fn panic_action_panics_and_disarms_cleanly() {
        let _gate = test_lock();
        set_schedule("boom=panic").unwrap();
        let r = std::panic::catch_unwind(|| point("boom"));
        assert!(r.is_err(), "panic action did not panic");
        // The lock was released before the panic; the schedule still works.
        assert!(point("boom").is_ok());
        clear();
    }

    #[test]
    fn delay_action_sleeps_then_continues() {
        let _gate = test_lock();
        set_schedule("slow=delay:10").unwrap();
        let t0 = std::time::Instant::now();
        assert!(point("slow").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(10));
        clear();
    }

    #[test]
    fn multi_clause_schedules_parse() {
        let _gate = test_lock();
        set_schedule("a=error; b=delay:5 x2, c=panic@7").unwrap();
        assert!(point("a").is_err());
        assert!(point("b").is_ok());
        assert!(point("c").is_ok()); // start=7, this is hit 1
        clear();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _gate = test_lock();
        assert!(set_schedule("no-equals-sign").is_err());
        assert!(set_schedule("p=explode").is_err());
        assert!(set_schedule("p=delay:abc").is_err());
        assert!(set_schedule("p=error@0").is_err());
        assert!(set_schedule("=error").is_err());
        // A failed parse must not leave a half-armed schedule.
        clear();
        assert!(point("p").is_ok());
    }

    #[test]
    fn fault_error_rides_anyhow() {
        let _gate = test_lock();
        set_schedule("deep=error").unwrap();
        fn io_like() -> anyhow::Result<()> {
            point("deep")?;
            Ok(())
        }
        let err = io_like().unwrap_err();
        assert!(err.to_string().contains("deep"), "{err}");
        clear();
    }
}
