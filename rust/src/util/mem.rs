//! Process memory introspection for the out-of-core memory assertions.
//!
//! The bounded-memory CI smoke trains a dataset several times larger
//! than the block budget and fails the run if the peak resident set
//! exceeds budget + slack (`lpdsvm train --max-rss-mb`). The reading
//! comes from the kernel's own high-water mark (`VmHWM` in
//! `/proc/self/status`), so it covers every allocation in the process —
//! there is no way for a resident-data-plane regression to hide from it.

/// Peak resident set size of this process in bytes (`VmHWM`), or `None`
/// where procfs is unavailable (non-Linux).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    parse_vm_hwm(&status)
}

fn parse_vm_hwm(status: &str) -> Option<u64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            // Format: "VmHWM:	  123456 kB"
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_vm_hwm_line() {
        let status = "Name:\tlpdsvm\nVmPeak:\t  999 kB\nVmHWM:\t  4321 kB\nVmRSS:\t 100 kB\n";
        assert_eq!(parse_vm_hwm(status), Some(4321 * 1024));
    }

    #[test]
    fn missing_field_is_none() {
        assert_eq!(parse_vm_hwm("Name:\tx\n"), None);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn live_reading_is_sane() {
        let peak = peak_rss_bytes().expect("procfs on linux");
        // A running test binary surely holds more than 1 MB and less
        // than 1 TB resident.
        assert!(peak > 1 << 20 && peak < 1 << 40, "peak {peak}");
    }
}
