//! Crash-safe file writes with integrity footers.
//!
//! Every durable artifact this crate produces (model files, training
//! checkpoints, grid journals) goes through the same two defenses:
//!
//! 1. **Atomic replace** — bytes are written to a same-directory temp
//!    file, fsync'd, then renamed over the destination (and the parent
//!    directory fsync'd on Unix, making the rename itself durable). A
//!    crash at any instant leaves either the complete old file or the
//!    complete new file, never a torn mixture.
//! 2. **CRC-32 footer** — the final four bytes are the checksum of
//!    everything before them, verified on read. Torn writes the rename
//!    dance cannot see (a dying disk, a truncating copy, bit rot) turn
//!    into a clean "checksum mismatch" error instead of a parsed-but-
//!    corrupt artifact.
//!
//! Each write declares a named fault point ([`crate::util::fault`]) in
//! the window between temp-write and rename — the exact instruction a
//! crash-recovery drill wants to die at.

use crate::util::fault;
use crate::util::hash::{crc32, Crc32};
use anyhow::Context;
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

fn temp_sibling(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    path.with_file_name(format!(".{name}.tmp.{}", std::process::id()))
}

/// Atomically replace `path` with `bytes`: temp write → fsync →
/// `fault_point` → rename → parent-dir fsync. On any error the
/// destination is untouched and the temp file is removed.
pub fn atomic_write(path: &Path, bytes: &[u8], fault_point: &str) -> anyhow::Result<()> {
    let tmp = temp_sibling(path);
    let result = (|| -> anyhow::Result<()> {
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating temp file {}", tmp.display()))?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        // The crash window under test: the temp file is durable but the
        // destination still holds the previous version.
        fault::point(fault_point)?;
        fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        #[cfg(unix)]
        if let Some(parent) = path.parent() {
            let dir = if parent.as_os_str().is_empty() { Path::new(".") } else { parent };
            // Make the rename durable: fsync the directory entry.
            File::open(dir)?.sync_all()?;
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    result.with_context(|| format!("atomic write of {}", path.display()))
}

/// [`atomic_write`] of `magic ‖ payload ‖ crc32(magic ‖ payload)`.
pub fn write_checksummed(
    path: &Path,
    magic: &[u8; 8],
    payload: &[u8],
    fault_point: &str,
) -> anyhow::Result<()> {
    let mut bytes = Vec::with_capacity(magic.len() + payload.len() + 4);
    bytes.extend_from_slice(magic);
    bytes.extend_from_slice(payload);
    let mut crc = Crc32::new();
    crc.update(&bytes);
    bytes.extend_from_slice(&crc.finish().to_le_bytes());
    atomic_write(path, &bytes, fault_point)
}

/// Read a [`write_checksummed`] file back, verifying magic and checksum.
/// Returns `Ok(None)` when the file does not exist; any other problem —
/// wrong magic, truncation, checksum mismatch — is an error naming the
/// file, because silently ignoring a corrupt artifact is how resumes go
/// wrong.
pub fn read_checksummed(path: &Path, magic: &[u8; 8]) -> anyhow::Result<Option<Vec<u8>>> {
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    anyhow::ensure!(
        bytes.len() >= magic.len() + 4,
        "{}: truncated ({} bytes)",
        path.display(),
        bytes.len()
    );
    anyhow::ensure!(
        &bytes[..magic.len()] == magic,
        "{}: bad magic — not a {} file (or an incompatible version)",
        path.display(),
        String::from_utf8_lossy(&magic[..magic.len() - 1]),
    );
    let (body, foot) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(foot.try_into().expect("4-byte footer"));
    let got = crc32(body);
    anyhow::ensure!(
        got == want,
        "{}: checksum mismatch (stored {want:#010x}, computed {got:#010x}) — \
         the file is corrupt or truncated",
        path.display()
    );
    Ok(Some(body[magic.len()..].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lpdsvm_fsio_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    const MAGIC: &[u8; 8] = b"LPDTEST\0";

    #[test]
    fn roundtrip() {
        let dir = temp_dir("roundtrip");
        let path = dir.join("a.bin");
        write_checksummed(&path, MAGIC, b"payload bytes", "test.none").unwrap();
        assert_eq!(read_checksummed(&path, MAGIC).unwrap().unwrap(), b"payload bytes");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_none() {
        let dir = temp_dir("missing");
        assert!(read_checksummed(&dir.join("nope.bin"), MAGIC).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_and_truncation_are_clean_errors() {
        let dir = temp_dir("corrupt");
        let path = dir.join("a.bin");
        write_checksummed(&path, MAGIC, b"some payload worth protecting", "test.none").unwrap();

        let clean = fs::read(&path).unwrap();
        let mut flipped = clean.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        fs::write(&path, &flipped).unwrap();
        let err = read_checksummed(&path, MAGIC).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err:#}");

        fs::write(&path, &clean[..clean.len() - 3]).unwrap();
        let err = read_checksummed(&path, MAGIC).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err:#}");

        fs::write(&path, b"xx").unwrap();
        let err = read_checksummed(&path, MAGIC).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err:#}");

        fs::write(&path, b"WRONGMG\0rest of a long enough file").unwrap();
        let err = read_checksummed(&path, MAGIC).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_between_tmp_and_rename_preserves_old_file() {
        let _gate = fault::test_lock();
        let dir = temp_dir("fault_window");
        let path = dir.join("a.bin");
        write_checksummed(&path, MAGIC, b"version one", "fsio.test.write").unwrap();

        fault::set_schedule("fsio.test.write=error").unwrap();
        let err = write_checksummed(&path, MAGIC, b"version two", "fsio.test.write").unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err:#}");
        fault::clear();

        // The old version survives intact and no temp litter remains.
        assert_eq!(read_checksummed(&path, MAGIC).unwrap().unwrap(), b"version one");
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp litter: {leftovers:?}");

        // And a retry after the fault clears goes through.
        write_checksummed(&path, MAGIC, b"version two", "fsio.test.write").unwrap();
        assert_eq!(read_checksummed(&path, MAGIC).unwrap().unwrap(), b"version two");
        let _ = fs::remove_dir_all(&dir);
    }
}
