//! Scoped worker pool built on `std::thread::scope` — the offline registry
//! has neither rayon nor tokio. The coordinator schedules many independent
//! binary SVM problems (OVO pairs × folds × grid points) over this pool,
//! mirroring the paper's OpenMP/multi-GPU job farm.
//!
//! The pool is work-stealing-free by design: jobs are pulled from a shared
//! atomic counter over an indexed job list, which is both simpler and
//! contention-free for the coarse-grained jobs we schedule (each job is an
//! entire SVM training run).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: respects `LPDSVM_THREADS`, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LPDSVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers and collect the
/// results in index order. `f` must be `Sync` (shared) — per-job state should
/// be created inside the closure.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<SlotPtr<T>> = out
        .iter_mut()
        .map(|s| SlotPtr(s as *mut Option<T>))
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed by exactly one worker via
                // the atomic counter, so each slot is written once with no
                // aliasing; the scope guarantees the borrow outlives workers.
                let slot: *mut Option<T> = slots[i].0;
                unsafe { *slot = Some(v) };
            });
        }
    });
    out.into_iter().map(|v| v.expect("job not run")).collect()
}

/// Covariant raw pointer wrapper so slots can be shared across the scope.
struct SlotPtr<T>(*mut Option<T>);
// SAFETY: disjoint writes enforced by the atomic job counter (see above).
unsafe impl<T: Send> Sync for SlotPtr<T> {}
unsafe impl<T: Send> Send for SlotPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_job_list() {
        let out: Vec<usize> = parallel_map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn heavy_closure_state_is_per_call() {
        // Each job builds its own Vec — no shared mutable state needed.
        let out = parallel_map(32, 8, |i| (0..i).sum::<usize>());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (0..i).sum::<usize>());
        }
    }
}
