//! Persistent worker pool — the offline registry has neither rayon nor
//! tokio. The coordinator schedules many independent binary SVM problems
//! (OVO pairs × folds × grid points) over this pool, mirroring the paper's
//! OpenMP/multi-GPU job farm; the stage-1 compute backbone (tiled GEMM,
//! kernel blocks, parallel Jacobi sweeps) submits its row bands to the
//! same pool.
//!
//! Until PR 3 every parallel section spawned fresh scoped threads
//! (`std::thread::scope`-per-call). That is fine at stage-1 granularity
//! but wasteful for the many small products of a CV/grid run, where
//! spawn/join cost rivals the work itself. [`ThreadPool`] keeps a fixed
//! set of long-lived workers behind a job queue instead; a process-wide
//! pool is spawned lazily on first use and shared by every call site
//! (including every [`crate::lowrank::factor::NativeBackend`]).
//!
//! Three primitives cover the granularity spectrum:
//! * [`parallel_map`] / [`ThreadPool::map`] — dynamic scheduling over an
//!   indexed job list via a shared atomic counter; right for coarse,
//!   uneven jobs (each job is an entire SVM training run, or one
//!   triangular Gram row).
//! * [`parallel_chunks`] / [`ThreadPool::chunks`] — static contiguous row
//!   bands over a mutable buffer; right for the regular, GEMM-shaped
//!   inner loops of the stage-1 compute backbone, where each band writes
//!   a disjoint slice of the output and per-row work is uniform.
//! * [`parallel_for_each`] / [`ThreadPool::for_each`] — fire-and-wait
//!   over an index range with no collected results; the building block
//!   for in-place updates with caller-proven disjointness (the parallel
//!   Jacobi rotation phases in `linalg::eigen`).
//!
//! Scheduling model: a submitted task is a set of `n` slots claimed from
//! an atomic counter. The *submitting thread always participates*, so a
//! task makes progress even when every pool worker is busy — which is
//! what makes nested submissions (a CV fold job whose stage-1 GEMM bands
//! hit the same pool) deadlock-free by construction. Work distribution
//! only decides *who* runs a slot, never *what* the slot computes, so
//! every pool-backed primitive keeps the bit-identity contract of
//! `tests/prop_parallel.rs`.

use crate::util::sync::{lock_or_abort, lock_recover, wait_or_abort};
use std::any::Any;
use std::ops::Range;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Number of worker threads to use: respects `LPDSVM_THREADS`, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LPDSVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// One submitted job set: `n` slots claimed from `claimed`, executed via
/// the type-erased `call(data, slot)` shim, completion tracked in
/// `completed`. `limit` caps how many pool workers may join (the caller
/// always participates on top of that).
struct Task {
    n: usize,
    limit: usize,
    claimed: AtomicUsize,
    completed: AtomicUsize,
    joined: AtomicUsize,
    /// Submission time — lets each joining worker account its dispatch
    /// latency (join time − enqueue time) as queue wait.
    enqueued: Instant,
    /// Pointer to the submitting call's closure. Only dereferenced for
    /// claims `< n`, all of which finish before `ThreadPool::run`
    /// returns, so the borrow never outlives the referent.
    data: *const (),
    /// SAFETY contract of the erased call: invoke only with this task's
    /// `data` and a slot index `< n` — see [`call_shim`].
    call: unsafe fn(*const (), usize),
    /// First panic payload from any slot, re-raised by the submitter.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

// SAFETY: `data` points at a `Sync` closure (enforced by the bounds on
// `ThreadPool::run`) that the submitting call keeps alive until every
// claimed slot completes; all other fields are atomics/locks.
unsafe impl Send for Task {}
unsafe impl Sync for Task {}

impl Task {
    /// Whether a scanning worker may still join this task. Checked (and
    /// `joined` bumped) only under the queue lock, so check-then-join is
    /// race-free — which is also why Relaxed loads suffice here: the
    /// queue mutex already orders them against the bumps.
    fn joinable(&self) -> bool {
        self.claimed.load(Ordering::Relaxed) < self.n
            && self.joined.load(Ordering::Relaxed) < self.limit
    }
}

struct PoolShared {
    /// Pending tasks in submission order; workers join the first
    /// joinable entry, so earlier (outer) submissions drain first.
    queue: Mutex<Vec<Arc<Task>>>,
    /// Signals workers that the queue changed (new task or shutdown).
    work_cv: Condvar,
    /// Completion signalling: submitters sleep here until their task's
    /// `completed` counter reaches `n`.
    done_mx: Mutex<()>,
    done_cv: Condvar,
    shutdown: AtomicBool,
    /// Per-worker utilization accounting, indexed like the handles.
    /// Always on: the counters move once per *task join*, not per slot,
    /// so the cost is a handful of relaxed adds per submission.
    stats: Vec<WorkerStat>,
}

/// Internal per-worker accumulators (µs resolution).
#[derive(Default)]
struct WorkerStat {
    tasks: AtomicU64,
    busy_us: AtomicU64,
    idle_us: AtomicU64,
    wait_us: AtomicU64,
}

/// Snapshot of one worker's lifetime accounting — see
/// [`ThreadPool::stats`].
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Tasks this worker joined (it may have run many slots of each).
    pub tasks: u64,
    /// Time spent executing slots.
    pub busy: Duration,
    /// Time spent parked waiting for work.
    pub idle: Duration,
    /// Summed dispatch latency: for each joined task, the gap between
    /// its submission and this worker picking it up.
    pub queue_wait: Duration,
}

/// Per-worker utilization snapshot of a [`ThreadPool`] — the source for
/// [`crate::obs::export::utilization_table`]. Covers only the pool's
/// long-lived workers; submitting threads execute slots too but are not
/// listed here.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<WorkerStats>,
}

/// Persistent worker pool: long-lived workers behind a job queue.
/// Construct with [`ThreadPool::new`], or share the lazily-spawned
/// process-wide instance via [`global`].
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `workers` long-lived threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> ThreadPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            stats: (0..workers).map(|_| WorkerStat::default()).collect(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lpdsvm-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, handles }
    }

    /// Snapshot the per-worker busy/idle/queue-wait accounting.
    pub fn stats(&self) -> PoolStats {
        // Relaxed: monotone telemetry counters; a snapshot needs no
        // ordering with the task data the workers touch.
        let us = |a: &AtomicU64| Duration::from_micros(a.load(Ordering::Relaxed));
        PoolStats {
            workers: self
                .shared
                .stats
                .iter()
                .map(|w| WorkerStats {
                    // Relaxed: same telemetry-snapshot reasoning as `us`.
                    tasks: w.tasks.load(Ordering::Relaxed),
                    busy: us(&w.busy_us),
                    idle: us(&w.idle_us),
                    queue_wait: us(&w.wait_us),
                })
                .collect(),
        }
    }

    /// Number of long-lived workers (excluding submitting threads, which
    /// also execute slots of their own tasks).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(i)` for every `i in 0..n` across the pool and collect the
    /// results in index order — the pool-backed equivalent of
    /// [`parallel_map`]. `threads` caps total parallelism (submitter
    /// plus joined workers); results are identical for every cap.
    pub fn map<T, F>(&self, n: usize, threads: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = threads.clamp(1, n.max(1));
        if threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let slots: Vec<SlotPtr<T>> = out
            .iter_mut()
            .map(|s| SlotPtr(s as *mut Option<T>))
            .collect();
        let job = |i: usize| {
            let v = f(i);
            let slot: *mut Option<T> = slots[i].0;
            // SAFETY: each slot index is claimed by exactly one
            // participant via the task's atomic counter, so each slot is
            // written once with no aliasing; `run` does not return until
            // every claimed slot has finished executing.
            unsafe { *slot = Some(v) };
        };
        self.run(n, threads, &job);
        out.into_iter().map(|v| v.expect("job not run")).collect()
    }

    /// Split `data` — a row-major buffer of `row_len`-element rows — into
    /// at most `threads` contiguous row bands and run `f(rows, band)` on
    /// each band across the pool — the pool-backed equivalent of
    /// [`parallel_chunks`]. Band boundaries depend only on `threads`
    /// (never on which worker runs a band), preserving bit-identity.
    pub fn chunks<T, F>(&self, data: &mut [T], row_len: usize, threads: usize, f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        if row_len == 0 || data.is_empty() {
            return;
        }
        let rows = checked_rows(data.len(), row_len);
        let threads = threads.clamp(1, rows);
        if threads <= 1 {
            f(0..rows, data);
            return;
        }
        let band = rows.div_ceil(threads);
        let bands: Vec<BandPtr<T>> = data
            .chunks_mut(band * row_len)
            .enumerate()
            .map(|(t, chunk)| BandPtr {
                start: t * band,
                ptr: chunk.as_mut_ptr(),
                len: chunk.len(),
            })
            .collect();
        let job = |t: usize| {
            let b = &bands[t];
            // SAFETY: the bands partition `data` into disjoint slices,
            // each band index is claimed exactly once, and `run` waits
            // for every claimed slot before returning — no aliasing and
            // no use after the borrow ends.
            let slice = unsafe { std::slice::from_raw_parts_mut(b.ptr, b.len) };
            f(b.start..b.start + b.len / row_len, slice);
        };
        self.run(bands.len(), threads, &job);
    }

    /// Run `f(i)` for every `i in 0..n` across the pool without
    /// collecting results — for in-place updates whose disjointness the
    /// caller proves (e.g. Jacobi rotations touching disjoint row/column
    /// pairs). `threads` caps total parallelism as in [`ThreadPool::map`].
    pub fn for_each<F>(&self, n: usize, threads: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let threads = threads.clamp(1, n.max(1));
        if threads <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.run(n, threads, &f);
    }

    /// Submit `n` slots and block until all have executed. The calling
    /// thread participates (so progress never depends on a free worker);
    /// at most `threads - 1` pool workers join it. Panics from slots are
    /// re-raised here after the task completes, mirroring the scoped-
    /// thread semantics this pool replaced.
    fn run<F>(&self, n: usize, threads: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let limit = threads.saturating_sub(1).min(self.handles.len());
        if limit == 0 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let task = Arc::new(Task {
            n,
            limit,
            claimed: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            joined: AtomicUsize::new(0),
            enqueued: Instant::now(),
            data: f as *const F as *const (),
            call: call_shim::<F>,
            panic: Mutex::new(None),
        });
        {
            let mut q = lock_or_abort(&self.shared.queue, "pool task queue");
            q.push(Arc::clone(&task));
        }
        self.shared.work_cv.notify_all();
        // Participate until the claim counter is exhausted.
        run_slots(&self.shared, &task);
        // De-list the task so late-waking workers skip it; any worker
        // already executing a claimed slot finishes independently.
        {
            let mut q = lock_or_abort(&self.shared.queue, "pool task queue");
            if let Some(pos) = q.iter().position(|t| Arc::ptr_eq(t, &task)) {
                q.remove(pos);
            }
        }
        // Wait for slots claimed by pool workers to finish executing.
        {
            let mut guard = lock_or_abort(&self.shared.done_mx, "pool completion");
            while task.completed.load(Ordering::Acquire) < task.n {
                guard = wait_or_abort(&self.shared.done_cv, guard, "pool completion");
            }
        }
        // lock_recover: the payload slot is a single `Option`, valid at
        // every statement boundary, and this runs after a slot panicked.
        let payload = lock_recover(&task.panic).take();
        if let Some(p) = payload {
            panic::resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // Publish the shutdown under the queue lock: a worker between
            // its shutdown check and its wait still holds that lock, so
            // the store-and-notify cannot slip into the gap and leave it
            // parked forever (a lost wakeup would hang the join below).
            let _q = lock_or_abort(&self.shared.queue, "pool task queue");
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Type-erasure shim: recover the concrete closure and run one slot.
///
/// # Safety
/// `data` must point to a live `F` — guaranteed by `ThreadPool::run`,
/// which keeps the closure borrowed until every claimed slot completes.
unsafe fn call_shim<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    let f = &*(data as *const F);
    f(i);
}

/// Claim and execute slots until the task's counter is exhausted. Shared
/// by pool workers and the submitting thread.
fn run_slots(shared: &PoolShared, task: &Task) {
    loop {
        // Relaxed: the claim counter only partitions indices between
        // participants; the closure itself was published to workers by
        // the queue mutex, and completion ordering is the Release below.
        let i = task.claimed.fetch_add(1, Ordering::Relaxed);
        if i >= task.n {
            return;
        }
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: i < n, so the closure is still alive (see `Task`).
            unsafe { (task.call)(task.data, i) };
        }));
        if let Err(payload) = result {
            // lock_recover: single-`Option` slot; this path is already
            // handling a panic and must not cascade another.
            let mut slot = lock_recover(&task.panic);
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let done = task.completed.fetch_add(1, Ordering::Release) + 1;
        if done == task.n {
            // Lock-then-notify so the submitter cannot miss the wakeup
            // between its predicate check and its wait.
            let _guard = lock_or_abort(&shared.done_mx, "pool completion");
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &PoolShared, idx: usize) {
    let stat = &shared.stats[idx];
    loop {
        let idle_from = Instant::now();
        let task = {
            let mut q = lock_or_abort(&shared.queue, "pool task queue");
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                let found = q.iter().find(|t| t.joinable()).map(Arc::clone);
                if let Some(t) = found {
                    // Relaxed: bumped under the queue lock (see
                    // `Task::joinable`), which provides the ordering.
                    t.joined.fetch_add(1, Ordering::Relaxed);
                    break t;
                }
                q = wait_or_abort(&shared.work_cv, q, "pool task queue");
            }
        };
        let joined_at = Instant::now();
        // Relaxed: per-worker telemetry counters, read only by stats()
        // snapshots; no ordering with task data is implied.
        stat.idle_us.fetch_add(
            joined_at.duration_since(idle_from).as_micros() as u64,
            Ordering::Relaxed,
        );
        // Relaxed: telemetry, as above.
        stat.wait_us.fetch_add(
            joined_at.saturating_duration_since(task.enqueued).as_micros() as u64,
            Ordering::Relaxed,
        );
        // Relaxed: telemetry, as above.
        stat.tasks.fetch_add(1, Ordering::Relaxed);
        {
            // One span per joined task (disarmed: one atomic check).
            let mut span = crate::obs::Span::new("pool.task");
            span.arg("worker", idx as f64);
            span.arg("slots", task.n as f64);
            run_slots(shared, &task);
        }
        // Relaxed: telemetry, as above.
        stat.busy_us
            .fetch_add(joined_at.elapsed().as_micros() as u64, Ordering::Relaxed);
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, spawned lazily on first use with
/// [`default_threads`] workers (`LPDSVM_THREADS` caps it). Every parallel
/// primitive in the crate funnels through this instance, so pool-side
/// compute threads stay fixed no matter how many subsystems (coordinator
/// job farm, serve workers, stage-1 backbone) submit concurrently —
/// total runnable threads are bounded by the pool plus the submitters,
/// each of which executes slots of its own task while it waits.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Utilization snapshot of the process-wide pool, if it was ever
/// spawned. `None` means every parallel section ran serially (or none
/// ran), so there is nothing to report.
pub fn global_stats() -> Option<PoolStats> {
    GLOBAL.get().map(ThreadPool::stats)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers of the
/// global pool and collect the results in index order. `f` must be `Sync`
/// (shared) — per-job state should be created inside the closure.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        // Serial path without touching (or lazily spawning) the pool.
        return (0..n).map(f).collect();
    }
    global().map(n, threads, f)
}

/// Split `data` — a row-major buffer of `row_len`-element rows — into at
/// most `threads` contiguous row bands and run `f(rows, band)` on each
/// band in parallel over the global pool. `rows` is the half-open range
/// of row indices the band covers and `band` is the mutable slice holding
/// exactly those rows, so every worker writes a disjoint region with no
/// synchronisation. This is the row-band backbone under the tiled GEMM
/// and the batch kernel blocks; because banding only partitions *rows*,
/// each output row is computed by exactly one worker in exactly the order
/// the serial path would use, and results are bit-identical for every
/// thread count.
///
/// Degenerate inputs are handled without scheduling: an empty buffer (or
/// `row_len == 0`) is a no-op, and `threads` is clamped to the row count.
/// A buffer that is not a whole number of rows is a hard error (a silent
/// `debug_assert!` here once dropped a trailing partial row in release
/// builds).
pub fn parallel_chunks<T, F>(data: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    if row_len == 0 || data.is_empty() {
        return;
    }
    let rows = checked_rows(data.len(), row_len);
    if threads.clamp(1, rows) <= 1 {
        f(0..rows, data);
        return;
    }
    global().chunks(data, row_len, threads, f)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers of the
/// global pool without collecting results — see [`ThreadPool::for_each`].
pub fn parallel_for_each<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    global().for_each(n, threads, f)
}

/// Hard shape check shared by the chunk primitives: a ragged buffer must
/// never be silently truncated to whole rows (the old `debug_assert!`
/// dropped a trailing partial row in release builds).
fn checked_rows(len: usize, row_len: usize) -> usize {
    let rows = len / row_len;
    assert!(
        rows * row_len == len,
        "parallel chunks: buffer of {len} elements is not a whole number of \
         {row_len}-element rows ({rows} full rows leave {} elements over)",
        len - rows * row_len
    );
    rows
}

/// Covariant raw pointer wrapper so result slots can be shared across the
/// pool workers.
struct SlotPtr<T>(*mut Option<T>);
// SAFETY: disjoint writes enforced by the task's atomic claim counter.
unsafe impl<T: Send> Sync for SlotPtr<T> {}
unsafe impl<T: Send> Send for SlotPtr<T> {}

/// Raw parts of one disjoint row band of a chunked buffer.
struct BandPtr<T> {
    start: usize,
    ptr: *mut T,
    len: usize,
}
// SAFETY: bands are disjoint slices of one buffer; each band is executed
// by exactly one claimant (see `ThreadPool::chunks`).
unsafe impl<T: Send> Sync for BandPtr<T> {}
unsafe impl<T: Send> Send for BandPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_job_list() {
        let out: Vec<usize> = parallel_map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn heavy_closure_state_is_per_call() {
        // Each job builds its own Vec — no shared mutable state needed.
        let out = parallel_map(32, 8, |i| (0..i).sum::<usize>());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (0..i).sum::<usize>());
        }
    }

    #[test]
    fn chunks_cover_all_rows_once() {
        // 13 rows of 5 elements over 4 threads: bands must tile the buffer.
        let mut data = vec![0u32; 13 * 5];
        parallel_chunks(&mut data, 5, 4, |rows, band| {
            assert_eq!(band.len(), rows.len() * 5);
            for (bi, r) in rows.enumerate() {
                for x in &mut band[bi * 5..(bi + 1) * 5] {
                    *x += 1 + r as u32;
                }
            }
        });
        for r in 0..13 {
            for c in 0..5 {
                // Each element written exactly once, by its own row's band.
                assert_eq!(data[r * 5 + c], 1 + r as u32, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn chunks_empty_input_is_noop() {
        let mut empty: Vec<f32> = Vec::new();
        parallel_chunks(&mut empty, 8, 4, |_, _| panic!("must not be called"));
        // row_len == 0 is equally degenerate.
        let mut data = vec![1.0f32; 4];
        parallel_chunks(&mut data, 0, 4, |_, _| panic!("must not be called"));
        assert_eq!(data, vec![1.0; 4]);
    }

    #[test]
    fn chunks_more_threads_than_rows() {
        let mut data = vec![0usize; 3 * 2];
        parallel_chunks(&mut data, 2, 64, |rows, band| {
            for (bi, r) in rows.enumerate() {
                band[bi * 2] = r;
                band[bi * 2 + 1] = r * 10;
            }
        });
        assert_eq!(data, vec![0, 0, 1, 10, 2, 20]);
    }

    #[test]
    fn chunks_single_thread_runs_inline() {
        let mut data = vec![0i32; 6];
        parallel_chunks(&mut data, 3, 1, |rows, band| {
            assert_eq!(rows, 0..2);
            assert_eq!(band.len(), 6);
            band[0] = 7;
        });
        assert_eq!(data[0], 7);
    }

    #[test]
    #[should_panic(expected = "not a whole number")]
    fn chunks_ragged_buffer_is_a_hard_error() {
        // 7 elements cannot be rows of 3 — must panic even in release
        // builds (a debug_assert here once silently dropped the tail).
        let mut data = vec![0f32; 7];
        parallel_chunks(&mut data, 3, 2, |_, _| {});
    }

    #[test]
    #[should_panic(expected = "not a whole number")]
    fn pool_chunks_ragged_buffer_is_a_hard_error() {
        let pool = ThreadPool::new(2);
        let mut data = vec![0f32; 10];
        pool.chunks(&mut data, 4, 2, |_, _| {});
    }

    #[test]
    fn pool_map_matches_serial() {
        let pool = ThreadPool::new(3);
        let want: Vec<usize> = (0..200).map(|i| i * i).collect();
        for t in [1usize, 2, 3, 8] {
            assert_eq!(pool.map(200, t, |i| i * i), want, "t={t}");
        }
    }

    #[test]
    fn pool_chunks_matches_serial() {
        let pool = ThreadPool::new(3);
        let mut want = vec![0u64; 17 * 3];
        pool.chunks(&mut want, 3, 1, |rows, band| {
            for (bi, r) in rows.enumerate() {
                for (c, x) in band[bi * 3..(bi + 1) * 3].iter_mut().enumerate() {
                    *x = (r * 100 + c) as u64;
                }
            }
        });
        for t in [2usize, 3, 8, 64] {
            let mut got = vec![0u64; 17 * 3];
            pool.chunks(&mut got, 3, t, |rows, band| {
                for (bi, r) in rows.enumerate() {
                    for (c, x) in band[bi * 3..(bi + 1) * 3].iter_mut().enumerate() {
                        *x = (r * 100 + c) as u64;
                    }
                }
            });
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn pool_for_each_runs_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each(64, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn pool_survives_reuse_across_many_submissions() {
        // The whole point of the persistent pool: many small tasks reuse
        // the same workers instead of respawning threads.
        let pool = ThreadPool::new(2);
        for round in 0..50 {
            let out = pool.map(8, 3, move |i| i + round);
            assert_eq!(out, (round..round + 8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // An outer job running on a pool worker submits its own task to
        // the same pool; caller participation guarantees progress even
        // with every worker busy.
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let out = pool.map(4, 4, move |i| {
            let inner = p2.map(6, 4, |j| j * 10);
            inner.iter().sum::<usize>() + i
        });
        assert_eq!(out, vec![150, 151, 152, 153]);
    }

    #[test]
    #[should_panic(expected = "boom from slot")]
    fn pool_repropagates_job_panics() {
        let pool = ThreadPool::new(2);
        pool.for_each(8, 4, |i| {
            if i == 5 {
                panic!("boom from slot {i}");
            }
        });
    }

    #[test]
    fn pool_usable_after_a_panicked_task() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.for_each(8, 4, |i| {
                if i == 2 {
                    panic!("transient");
                }
            });
        }));
        assert!(r.is_err());
        // Workers survived the unwound job and keep serving.
        assert_eq!(pool.map(5, 3, |i| i * 3), vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        assert!(
            std::ptr::eq(global(), global()),
            "global() must hand back one shared pool"
        );
        assert!(global().workers() >= 1);
    }

    #[test]
    fn worker_stats_account_joined_tasks() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.stats().workers.len(), 2);
        // Coarse slots so the workers reliably get to join before the
        // submitter drains the claim counter on its own.
        for _ in 0..20 {
            pool.map(64, 3, |i| {
                std::hint::black_box((0..500 + i).sum::<usize>())
            });
        }
        let stats = pool.stats();
        let joined: u64 = stats.workers.iter().map(|w| w.tasks).sum();
        assert!(joined > 0, "no worker ever joined a task");
        // Busy time only accumulates on a join (µs-rounded, so it may be
        // zero even for a joined task — but never without one).
        for w in &stats.workers {
            if w.tasks == 0 {
                assert_eq!(w.busy, Duration::ZERO);
            }
        }
    }

    #[test]
    fn parallel_for_each_serial_path() {
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_each(5, 1, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
