//! Scoped worker pool built on `std::thread::scope` — the offline registry
//! has neither rayon nor tokio. The coordinator schedules many independent
//! binary SVM problems (OVO pairs × folds × grid points) over this pool,
//! mirroring the paper's OpenMP/multi-GPU job farm.
//!
//! Two primitives cover both ends of the granularity spectrum:
//! * [`parallel_map`] — dynamic scheduling over an indexed job list via a
//!   shared atomic counter; right for coarse, uneven jobs (each job is an
//!   entire SVM training run, or one triangular Gram row).
//! * [`parallel_chunks`] — static contiguous row bands over a mutable
//!   buffer; right for the regular, GEMM-shaped inner loops of the stage-1
//!   compute backbone, where each band writes a disjoint slice of the
//!   output and per-row work is uniform.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: respects `LPDSVM_THREADS`, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LPDSVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers and collect the
/// results in index order. `f` must be `Sync` (shared) — per-job state should
/// be created inside the closure.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<SlotPtr<T>> = out
        .iter_mut()
        .map(|s| SlotPtr(s as *mut Option<T>))
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed by exactly one worker via
                // the atomic counter, so each slot is written once with no
                // aliasing; the scope guarantees the borrow outlives workers.
                let slot: *mut Option<T> = slots[i].0;
                unsafe { *slot = Some(v) };
            });
        }
    });
    out.into_iter().map(|v| v.expect("job not run")).collect()
}

/// Split `data` — a row-major buffer of `row_len`-element rows — into at
/// most `threads` contiguous row bands and run `f(rows, band)` on each
/// band in parallel. `rows` is the half-open range of row indices the band
/// covers and `band` is the mutable slice holding exactly those rows, so
/// every worker writes a disjoint region with no synchronisation. This is
/// the row-band backbone under the tiled GEMM and the batch kernel blocks;
/// because banding only partitions *rows*, each output row is computed by
/// exactly one worker in exactly the order the serial path would use, and
/// results are bit-identical for every thread count.
///
/// Degenerate inputs are handled without spawning: an empty buffer (or
/// `row_len == 0`) is a no-op, and `threads` is clamped to the row count.
pub fn parallel_chunks<T, F>(data: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    if row_len == 0 || data.is_empty() {
        return;
    }
    let rows = data.len() / row_len;
    debug_assert_eq!(rows * row_len, data.len(), "buffer is not whole rows");
    let threads = threads.clamp(1, rows.max(1));
    if threads <= 1 {
        f(0..rows, data);
        return;
    }
    let band = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, chunk) in data.chunks_mut(band * row_len).enumerate() {
            let f = &f;
            let start = t * band;
            let end = start + chunk.len() / row_len;
            scope.spawn(move || f(start..end, chunk));
        }
    });
}

/// Covariant raw pointer wrapper so slots can be shared across the scope.
struct SlotPtr<T>(*mut Option<T>);
// SAFETY: disjoint writes enforced by the atomic job counter (see above).
unsafe impl<T: Send> Sync for SlotPtr<T> {}
unsafe impl<T: Send> Send for SlotPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_job_list() {
        let out: Vec<usize> = parallel_map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn heavy_closure_state_is_per_call() {
        // Each job builds its own Vec — no shared mutable state needed.
        let out = parallel_map(32, 8, |i| (0..i).sum::<usize>());
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (0..i).sum::<usize>());
        }
    }

    #[test]
    fn chunks_cover_all_rows_once() {
        // 13 rows of 5 elements over 4 threads: bands must tile the buffer.
        let mut data = vec![0u32; 13 * 5];
        parallel_chunks(&mut data, 5, 4, |rows, band| {
            assert_eq!(band.len(), rows.len() * 5);
            for (bi, r) in rows.enumerate() {
                for x in &mut band[bi * 5..(bi + 1) * 5] {
                    *x += 1 + r as u32;
                }
            }
        });
        for r in 0..13 {
            for c in 0..5 {
                // Each element written exactly once, by its own row's band.
                assert_eq!(data[r * 5 + c], 1 + r as u32, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn chunks_empty_input_is_noop() {
        let mut empty: Vec<f32> = Vec::new();
        parallel_chunks(&mut empty, 8, 4, |_, _| panic!("must not be called"));
        // row_len == 0 is equally degenerate.
        let mut data = vec![1.0f32; 4];
        parallel_chunks(&mut data, 0, 4, |_, _| panic!("must not be called"));
        assert_eq!(data, vec![1.0; 4]);
    }

    #[test]
    fn chunks_more_threads_than_rows() {
        let mut data = vec![0usize; 3 * 2];
        parallel_chunks(&mut data, 2, 64, |rows, band| {
            for (bi, r) in rows.enumerate() {
                band[bi * 2] = r;
                band[bi * 2 + 1] = r * 10;
            }
        });
        assert_eq!(data, vec![0, 0, 1, 10, 2, 20]);
    }

    #[test]
    fn chunks_single_thread_runs_inline() {
        let mut data = vec![0i32; 6];
        parallel_chunks(&mut data, 3, 1, |rows, band| {
            assert_eq!(rows, 0..2);
            assert_eq!(band.len(), 6);
            band[0] = 7;
        });
        assert_eq!(data[0], 7);
    }
}
