//! Lock acquisition with an explicit poisoning policy.
//!
//! `Mutex::lock().unwrap()` makes a silent policy decision: a panic on
//! any thread that held the lock later panics *this* thread too. The
//! crate used that default at ~26 sites; this module replaces them
//! with three named policies so every call site states which failure
//! semantics it wants — and the lint engine's lock-order rule can
//! recognise all acquisition forms uniformly.
//!
//! - [`lock_or_abort`] — **compute and scheduler state.** The guarded
//!   state has multi-field invariants (the pool's task queue, the
//!   serve engine's ring/queues/depth accounting) that a mid-update
//!   panic may have torn. Continuing could silently break the
//!   bit-identity contract or the serve metrics conservation law, so
//!   the process aborts; crash-safe checkpointing and the supervisor
//!   are the recovery story (crash-only design).
//! - [`lock_checked`] — **fallible serve boundaries.** Client-facing
//!   paths that already return `Result` surface poisoning as a typed
//!   error (`ServeError::Poisoned` via `From<PoisonedLock>`) instead
//!   of panicking a connection thread.
//! - [`lock_recover`] — **single-field observability state.** Span
//!   ring buffers, fault schedules, ticket slots: every value the
//!   guard protects is valid at every statement boundary, so the
//!   poison flag carries no information and the data is safe to use.
//!
//! Condvar waits on policy-locked state use the matching
//! [`wait_or_abort`] / [`wait_timeout_or_abort`].

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Typed poisoning error for fallible lock acquisition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoisonedLock {
    /// Human-readable name of the lock, for diagnostics.
    pub what: &'static str,
}

impl std::fmt::Display for PoisonedLock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lock poisoned: {}", self.what)
    }
}

impl std::error::Error for PoisonedLock {}

/// Acquire a lock whose state must never be observed after a
/// mid-update panic. Poisoning aborts the process with a diagnostic
/// instead of unwinding further: for training state the checkpoint
/// layer replays the run bit-identically, for the serve engine the
/// process supervisor restarts a coherent world.
pub fn lock_or_abort<'a, T>(m: &'a Mutex<T>, what: &'static str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(_) => die(what),
    }
}

/// Acquire a lock on a fallible path, mapping poisoning to a typed
/// error the caller can surface (`ServeError::Poisoned` on the serve
/// request path).
pub fn lock_checked<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<MutexGuard<'a, T>, PoisonedLock> {
    m.lock().map_err(|_| PoisonedLock { what })
}

/// Acquire a lock whose guarded value is valid at every statement
/// boundary (single-field slots, append-only buffers): recover the
/// data and ignore the poison flag. Telemetry must keep working after
/// an unrelated panic, and a panicking recorder must never cascade.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Condvar wait matching the [`lock_or_abort`] policy.
pub fn wait_or_abort<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    what: &'static str,
) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(_) => die(what),
    }
}

/// Timed condvar wait matching the [`lock_or_abort`] policy.
pub fn wait_timeout_or_abort<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
    what: &'static str,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    match cv.wait_timeout(g, dur) {
        Ok(r) => r,
        Err(_) => die(what),
    }
}

fn die(what: &'static str) -> ! {
    // Abort, not panic: unwinding out of a poisoned-state observation
    // would run Drop impls over state already known to be torn.
    eprintln!(
        "lpdsvm: fatal: lock `{}` poisoned by a panic on another thread; \
         aborting (crash-only recovery: checkpoints / supervisor)",
        what
    );
    std::process::abort();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_or_abort_plain() {
        let m = Mutex::new(7);
        assert_eq!(*lock_or_abort(&m, "t"), 7);
    }

    #[test]
    fn lock_checked_maps_poison() {
        let m = Arc::new(Mutex::new(1));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let err = lock_checked(&m, "unit").unwrap_err();
        assert_eq!(err, PoisonedLock { what: "unit" });
        assert_eq!(err.to_string(), "lock poisoned: unit");
    }

    #[test]
    fn lock_recover_reads_through_poison() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 42;
            panic!("poison it");
        })
        .join();
        assert_eq!(*lock_recover(&m), 42);
    }
}
