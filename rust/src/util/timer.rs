//! Wall-clock stage timing used throughout the benches and the figure-3
//! breakdown. Deliberately tiny: `Timer` measures one span, `StageClock`
//! accumulates named stages (preparation / G computation / linear training)
//! exactly as the paper's figure 3 reports them.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One-shot wall clock.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates durations under stage names, preserving insertion order via
/// BTreeMap keys prefixed by first-seen index.
#[derive(Default, Clone)]
pub struct StageClock {
    stages: BTreeMap<String, Duration>,
    order: Vec<String>,
}

impl StageClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and accumulate under `stage`. When tracing is on, the
    /// stage also rides as a span named `stage.<name>` (the name
    /// allocation is gated, so the disabled cost stays one atomic check).
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let _span = if crate::obs::enabled() {
            Some(crate::obs::Span::new(format!("stage.{stage}")))
        } else {
            None
        };
        let t = Instant::now();
        let out = f();
        self.add(stage, t.elapsed());
        out
    }

    pub fn add(&mut self, stage: &str, d: Duration) {
        if !self.stages.contains_key(stage) {
            self.order.push(stage.to_string());
        }
        *self.stages.entry(stage.to_string()).or_default() += d;
    }

    pub fn get(&self, stage: &str) -> Duration {
        self.stages.get(stage).copied().unwrap_or_default()
    }

    pub fn secs(&self, stage: &str) -> f64 {
        self.get(stage).as_secs_f64()
    }

    pub fn total(&self) -> Duration {
        self.stages.values().copied().sum()
    }

    /// Stages in first-seen order with accumulated seconds.
    pub fn entries(&self) -> Vec<(String, f64)> {
        self.order
            .iter()
            .map(|k| (k.clone(), self.secs(k)))
            .collect()
    }

    /// Merge another clock into this one (used when joining worker results).
    pub fn merge(&mut self, other: &StageClock) {
        for (k, v) in other.entries() {
            self.add(&k, Duration::from_secs_f64(v));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_stages() {
        let mut c = StageClock::new();
        c.add("prep", Duration::from_millis(10));
        c.add("prep", Duration::from_millis(5));
        c.add("train", Duration::from_millis(1));
        assert!((c.secs("prep") - 0.015).abs() < 1e-9);
        assert_eq!(c.entries().len(), 2);
        assert_eq!(c.entries()[0].0, "prep");
    }

    #[test]
    fn time_closure_returns_value() {
        let mut c = StageClock::new();
        let v = c.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(c.secs("work") >= 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = StageClock::new();
        a.add("x", Duration::from_millis(2));
        let mut b = StageClock::new();
        b.add("x", Duration::from_millis(3));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert!((a.secs("x") - 0.005).abs() < 1e-9);
        assert!((a.secs("y") - 0.001).abs() < 1e-9);
    }
}
