//! Minimal JSON value + writer + parser (serde is unavailable offline).
//!
//! Used for: the artifacts manifest written by `python/compile/aot.py`,
//! model save/load, and bench report files. Supports the JSON subset those
//! producers emit (no surrogate escapes, numbers as f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// Non-negative integer view of a number (counters, ids). `None` for
    /// negative numbers and non-numbers.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// [`obj`] for runtime-computed keys (e.g. per-model metric sections
/// keyed by tenant name). Duplicate keys keep the last value; emission
/// order is the `BTreeMap` key order, so output stays deterministic.
pub fn obj_owned(pairs: impl IntoIterator<Item = (String, Json)>) -> Json {
    Json::Obj(pairs.into_iter().collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
/// `u64` counter as a JSON number. The f64 payload is exact below 2⁵³;
/// larger counters round, which telemetry consumers tolerate.
pub fn unum(x: u64) -> Json {
    Json::Num(x as f64)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            )
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        c => anyhow::bail!("expected ',' or ']' found '{}'", c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        c => anyhow::bail!("expected ',' or '}}' found '{}'", c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-synchronise on UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = (start + width).min(self.b.len());
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_width(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = obj(vec![
            ("name", s("rbf_gram")),
            ("m", num(256.0)),
            ("ok", Json::Bool(true)),
            ("tags", arr(vec![s("a"), s("b")])),
            ("none", Json::Null),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": -3e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -300.0);
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), 2.5);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.5).to_string(), "3.5");
    }

    #[test]
    fn obj_owned_builds_from_dynamic_keys() {
        let v = obj_owned(vec![
            ("hot".to_string(), num(1.0)),
            ("cold".to_string(), num(2.0)),
        ]);
        // BTreeMap ordering makes emission deterministic and sorted.
        assert_eq!(v.to_string(), r#"{"cold":2,"hot":1}"#);
        assert_eq!(v.get("hot").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn u64_builder_and_accessors() {
        assert_eq!(unum(42).to_string(), "42");
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("\"x\"").unwrap().as_u64(), None);
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = s("quote\" slash\\ tab\t nl\n");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_passthrough() {
        let v = s("λ-svm αβγ");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_python_json_output() {
        // Shape emitted by python's json.dump for the artifacts manifest.
        let text = r#"{"artifacts": [{"name": "stage1", "file": "stage1_m256_b128_p64.hlo.txt", "m": 256, "b": 128, "p": 64}], "version": 1}"#;
        let v = Json::parse(text).unwrap();
        let a = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(a[0].get("m").unwrap().as_usize().unwrap(), 256);
    }
}
