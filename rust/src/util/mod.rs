//! Self-contained substitutes for crates unavailable in this offline
//! environment (clap, rand, tokio/rayon, serde, criterion). See
//! DESIGN.md §2.
//!
//! Paper role: [`threads`] is the paper's "parallelism" substrate — the
//! persistent worker pool every parallel primitive in the crate submits
//! to (GEMM row bands, kernel blocks, the OVO job farm, the tournament
//! eigensolver, serve-worker scoring).
//!
//! Invariants: the global pool is spawned lazily and sized once from
//! `LPDSVM_THREADS` (or all cores); the submitting thread always
//! participates in its own task, so nested submissions cannot deadlock;
//! a slot panic is re-raised on the submitter (scoped-thread semantics);
//! band layout depends only on the requested thread cap, never on pool
//! size, so parallel results are bit-identical to serial. [`rng`] is a
//! seeded SplitMix/xoshiro-style generator: every randomised stage is
//! reproducible from its recorded seed. [`json`] round-trips the subset
//! of JSON the repo emits (numbers as f64, exact for integers < 2⁵³).

pub mod cli;
pub mod fault;
pub mod fsio;
pub mod hash;
pub mod json;
pub mod mem;
#[cfg(target_os = "linux")]
pub mod net;
pub mod rng;
pub mod sync;
pub mod threads;
pub mod timer;
