//! Self-contained substitutes for crates unavailable in this offline
//! environment (clap, rand, tokio/rayon, serde, criterion). See
//! DESIGN.md §2. `threads` hosts the persistent worker pool every
//! parallel primitive in the crate submits to.

pub mod cli;
pub mod json;
pub mod rng;
pub mod threads;
pub mod timer;
