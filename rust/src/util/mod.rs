//! Self-contained substitutes for crates unavailable in this offline
//! environment (clap, rand, tokio, serde, criterion). See DESIGN.md §2.

pub mod cli;
pub mod json;
pub mod rng;
pub mod threads;
pub mod timer;
