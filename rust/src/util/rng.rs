//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so we ship a small,
//! well-understood generator: SplitMix64 for seeding and xoshiro256++ for
//! the stream. Determinism matters here — every synthetic dataset, landmark
//! sample, and CD permutation in the experiments is reproducible from a
//! single `u64` seed.

/// xoshiro256++ generator seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-job RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw xoshiro256++ state, for checkpointing. Restoring it with
    /// [`Rng::from_state`] resumes the stream exactly where it stopped —
    /// the solver's bit-identity-across-resume contract depends on this.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a state captured by [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64 bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// our purposes via 64-bit multiply-shift).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::usize: empty range");
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use a set-free reservoir-ish approach:
        // partial shuffle of an index vector is O(n) memory but simple and
        // never on a hot path (landmark selection happens once per grid).
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn usize_bounds() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(r.usize(n) < n);
            }
        }
    }

    #[test]
    fn usize_covers_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.usize(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30, "duplicates in sample");
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
