//! # LPD-SVM — Low-rank Parallel Dual SVM
//!
//! Reproduction of T. Glasmachers, *"Recipe for Fast Large-scale SVM
//! Training: Polishing, Parallelism, and more RAM!"* (2022), as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Stage 1** ([`lowrank`]): Nyström landmark sampling, eigendecomposition
//!   of `K_BB` with adaptive rank truncation, and complete precomputation of
//!   the factor `G = K_nB·V·Λ^{-1/2}` — natively ([`lowrank::factor::NativeBackend`])
//!   or through AOT-compiled JAX+Pallas artifacts on PJRT ([`runtime`]).
//! * **Stage 2** ([`solver`]): dual coordinate ascent over the rows of `G`
//!   with the paper's shrinking, stopping, and warm-start polish.
//! * **Coordination** ([`coordinator`]): one-versus-one multiclass,
//!   cross-validation and grid search that share `G`, scheduled over a
//!   thread pool.
//! * **Baselines** ([`baselines`]): an exact dual SMO solver
//!   (LIBSVM/ThunderSVM-style) and an LLSVM-style chunked solver for the
//!   paper's table 2 comparison.
//! * **Serving** ([`serve`]): a micro-batching inference engine over
//!   trained models — request coalescing under a latency/size policy,
//!   per-model bounded queues scheduled by weighted deficit-round-robin
//!   (multi-tenant fairness: a hot model sheds only its own traffic and
//!   cannot starve a cold one), admission control with explicit load
//!   shedding under saturation, a hot-swappable model registry,
//!   per-request tickets, latency/throughput metrics with per-model
//!   rollups, and a dependency-free HTTP/1.1 front-end with a bounded
//!   connection pool, reusing the same `Stage1Backend` abstraction so
//!   batches score through native GEMM or the PJRT path.
//! * **Observability** ([`obs`]): dependency-free tracing spans across
//!   train/solve/serve with Chrome-trace (Perfetto) export, a leveled
//!   `key=value` stderr logger, a Prometheus view of the serve metrics,
//!   and per-worker pool utilization accounting — zero cost when off.
//! * **Static analysis** ([`analysis`]): an in-repo invariant lint
//!   engine (`lpdsvm lint`) that statically enforces the bit-identity
//!   and concurrency contracts — SAFETY comments on every `unsafe`
//!   site, justified relaxed atomics, a nondeterminism-free solver
//!   domain, an acyclic lock-order graph, a panic-free serve request
//!   path, and a closed fault-point registry.
//!
//! Quickstart:
//!
//! ```no_run
//! use lpdsvm::prelude::*;
//!
//! let spec = PaperDataset::Adult.spec(0.02, 42);
//! let data = spec.synth.generate();
//! let cfg = TrainConfig {
//!     kernel: Kernel::gaussian(spec.gamma),
//!     stage1: Stage1Config { budget: spec.budget, ..Default::default() },
//!     solver: SolverOptions { c: spec.c, ..Default::default() },
//!     ..Default::default()
//! };
//! let model = train(&data, &cfg).unwrap();
//! let preds = model.predict(&data.x).unwrap();
//! ```

pub mod analysis;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod kernel;
pub mod linalg;
pub mod lowrank;
pub mod model;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod testing;
pub mod util;

pub use coordinator::train::{train, TrainConfig};

/// Convenient single-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::baselines::exact_smo::{ExactSmo, ExactSmoOptions};
    pub use crate::baselines::llsvm::{Llsvm, LlsvmOptions};
    pub use crate::coordinator::cv::{cross_validate, CvConfig};
    pub use crate::coordinator::regression::{train_svr, SvrModel, SvrTrainConfig};
    pub use crate::coordinator::grid::{grid_search, GridConfig, GridResult};
    pub use crate::coordinator::train::{train, train_with_backend, TrainConfig};
    pub use crate::data::dataset::Dataset;
    pub use crate::data::sparse::SparseMatrix;
    pub use crate::data::synth::{FeatureStyle, PaperDataset, PaperSpec, SynthSpec};
    pub use crate::kernel::Kernel;
    pub use crate::linalg::Mat;
    pub use crate::lowrank::factor::NativeBackend;
    pub use crate::lowrank::{LowRankFactor, Stage1Backend, Stage1Config};
    pub use crate::model::multiclass::MulticlassModel;
    pub use crate::model::ModelKind;
    pub use crate::serve::{
        HttpServer, ModelMetrics, ModelRegistry, ModelServeConfig, PredictResult, Prediction,
        ServeConfig, ServeEngine, ServeError, ServingModel, ShedPolicy,
    };
    pub use crate::solver::{solve, Solution, SolverOptions};
    pub use crate::util::rng::Rng;
    pub use crate::util::timer::StageClock;
}
