//! `lpdsvm` — command-line interface to the LPD-SVM system.
//!
//! Subcommands:
//!   gen-data   synthesise a paper-analogue dataset in LIBSVM format
//!   train      train a model (binary or OVO multiclass)
//!   predict    predict with a saved model, report error if labels given
//!   cv         k-fold cross validation (stage 1 shared across folds)
//!   grid       (C, γ) grid search with CV, warm starts, G-reuse
//!   serve      micro-batching inference engine, HTTP front-end, load generator
//!   info       show artifact / runtime information
//!   lint       in-repo invariant lint engine (static analysis, CI gate)
//!
//! Every workload command takes `--log-level` (leveled diagnostics on
//! stderr) and `--trace <path>` (span recording + Chrome-trace JSON
//! export, plus phase/pool-utilization summary tables). Result output —
//! report tables, summary lines — intentionally stays on stdout so it
//! pipes cleanly past the diagnostics.

use lpdsvm::coordinator::checkpoint::CheckpointCtx;
use lpdsvm::coordinator::cv::{cross_validate_ckpt, cross_validate_streaming, CvConfig};
use lpdsvm::coordinator::grid::{grid_search_ckpt, GridConfig};
use lpdsvm::coordinator::train::{
    streaming_error_rate, train_streaming, train_with_backend, train_with_backend_ckpt,
    TrainConfig,
};
use lpdsvm::data::sparse::SparseMatrix;
use lpdsvm::data::synth::PaperDataset;
use lpdsvm::data::{dataset::Dataset, libsvm, DataSource, MemorySource, ShardedSource};
use lpdsvm::kernel::Kernel;
use lpdsvm::lowrank::factor::NativeBackend;
use lpdsvm::lowrank::{Stage1Backend, Stage1Config};
use lpdsvm::model::io as model_io;
use lpdsvm::model::multiclass::error_rate;
use lpdsvm::report::Table;
use lpdsvm::runtime::{AccelBackend, Runtime};
use lpdsvm::serve::{
    BackendProvider, HttpOptions, HttpServer, IoModel, ModelRegistry, ModelServeConfig,
    NativeProvider, PjrtProvider, ServeConfig, ServeEngine, ShedPolicy,
};
use lpdsvm::solver::SolverOptions;
use lpdsvm::util::cli::{parse, ArgSpec};
use lpdsvm::util::timer::StageClock;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // Arm the deterministic fault-injection harness before anything can
    // hit a fault point. A malformed schedule is a usage error: fail
    // loudly up front rather than silently running without faults.
    if let Err(e) = lpdsvm::util::fault::init_from_env() {
        eprintln!("error: invalid LPDSVM_FAULTS: {e:#}");
        std::process::exit(2);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_usage();
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "gen-data" => cmd_gen_data(&rest),
        "split" => cmd_split(&rest),
        "train" => cmd_train(&rest),
        "predict" => cmd_predict(&rest),
        "cv" => cmd_cv(&rest),
        "grid" => cmd_grid(&rest),
        "serve" => cmd_serve(&rest),
        "info" => cmd_info(&rest),
        "lint" => cmd_lint(&rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "lpdsvm — Low-rank Parallel Dual SVM (Glasmachers 2022 reproduction)\n\n\
         Usage: lpdsvm <command> [options]   (each command supports --help)\n\n\
         Commands:\n\
           gen-data   synthesise a paper-analogue dataset (LIBSVM format)\n\
           split      shard a LIBSVM file into block files for out-of-core training\n\
           train      train a model and save it\n\
           predict    predict with a saved model\n\
           cv         k-fold cross-validation\n\
           grid       (C, gamma) grid search with CV + warm starts\n\
           serve      batched inference engine (optional HTTP front-end) + load generator\n\
           info       artifact/runtime information\n\
           lint       invariant lint engine over the crate sources (exit 1 on findings)\n\n\
         Out-of-core: train/cv/grid accept --block-budget-mb and/or --shards to\n\
         stream feature blocks under a fixed byte budget instead of holding the\n\
         dataset and G resident; models are byte-identical at any budget."
    );
}

fn load_data(path: &str) -> anyhow::Result<Dataset> {
    libsvm::read(Path::new(path))
}

fn backend_args() -> Vec<ArgSpec> {
    vec![ArgSpec::opt(
        "backend",
        "native",
        "stage-1 backend: native | pjrt",
    )]
}

fn obs_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt(
            "trace",
            "",
            "record spans and write a Chrome-trace JSON (Perfetto) here",
        ),
        ArgSpec::opt(
            "log-level",
            "info",
            "stderr log level: error | warn | info | debug | trace",
        ),
    ]
}

/// Apply the shared observability flags: set the logger level and, when
/// `--trace` names a file, arm span recording for the whole run.
fn obs_setup(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<()> {
    lpdsvm::obs::log::set_level_str(p.str("log-level"))?;
    if !p.str("trace").is_empty() {
        lpdsvm::obs::span::enable();
    }
    Ok(())
}

/// Flush the recorded spans: write the Chrome trace and print the
/// per-phase and pool-utilization summaries. No-op without `--trace`.
fn obs_finish(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<()> {
    let path = p.str("trace");
    if path.is_empty() {
        return Ok(());
    }
    lpdsvm::obs::span::disable();
    let dumps = lpdsvm::obs::span::drain();
    lpdsvm::obs::export::write_chrome_trace(Path::new(path), &dumps)?;
    // The summaries are results, like the report tables: stdout.
    lpdsvm::obs::export::phase_table(&dumps).print();
    if let Some(stats) = lpdsvm::util::threads::global_stats() {
        lpdsvm::obs::export::utilization_table(&stats).print();
    }
    let events: usize = dumps.iter().map(|d| d.records.len()).sum();
    let dropped: u64 = dumps.iter().map(|d| d.dropped).sum();
    println!(
        "wrote {events} trace events ({dropped} dropped) from {} threads to {path}",
        dumps.len()
    );
    Ok(())
}

/// Run `f` with the requested backend (constructing the PJRT runtime on
/// demand so the native path never touches artifacts).
fn with_backend<T>(
    name: &str,
    f: impl FnOnce(&dyn Stage1Backend) -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    match name {
        "native" => f(&NativeBackend::default()),
        "pjrt" => {
            let rt = Runtime::load(&Runtime::default_dir())?;
            let backend = AccelBackend::new(&rt);
            f(&backend)
        }
        other => anyhow::bail!("unknown backend '{other}' (native | pjrt)"),
    }
}

/// Serving-engine counterpart of [`with_backend`]: same names, same
/// validation, but yields a per-worker provider instead of one backend.
fn provider_for(name: &str) -> anyhow::Result<Arc<dyn BackendProvider>> {
    Ok(match name {
        "native" => Arc::new(NativeProvider),
        "pjrt" => Arc::new(PjrtProvider::default()),
        other => anyhow::bail!("unknown backend '{other}' (native | pjrt)"),
    })
}

fn cmd_gen_data(args: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        ArgSpec::opt(
            "dataset",
            "adult",
            "adult | epsilon | susy | mnist8m | imagenet",
        ),
        ArgSpec::opt("scale", "0.01", "fraction of the paper's n in (0,1]"),
        ArgSpec::opt("seed", "42", "RNG seed"),
        ArgSpec::opt("out", "", "output path (LIBSVM format)"),
        ArgSpec::flag("list", "list dataset specs and exit"),
    ];
    let p = parse("gen-data", "Synthesise a paper-analogue dataset", &specs, args)?;
    if p.flag("list") {
        let mut t = Table::new(
            "paper datasets (table 1 analogues)",
            &["name", "n(full)", "p", "classes", "B", "C", "gamma"],
        );
        for d in PaperDataset::all() {
            let s = d.spec(1.0, 0);
            t.row(&[
                d.name().into(),
                s.synth.n.to_string(),
                s.synth.p.to_string(),
                s.synth.n_classes.to_string(),
                s.budget.to_string(),
                s.c.to_string(),
                format!("{:e}", s.gamma),
            ]);
        }
        t.print();
        return Ok(());
    }
    anyhow::ensure!(!p.str("out").is_empty(), "--out is required (or use --list)");
    let dataset = PaperDataset::from_name(p.str("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", p.str("dataset")))?;
    let spec = dataset.spec(p.f64("scale")?, p.u64("seed")?);
    let data = spec.synth.generate();
    libsvm::write(&data, Path::new(p.str("out")))?;
    println!(
        "wrote {} ({} points, {} features, {} classes, density {:.3}) to {}",
        data.name,
        data.len(),
        data.dim(),
        data.n_classes,
        data.x.density(),
        p.str("out")
    );
    Ok(())
}

fn train_cfg_from(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<TrainConfig> {
    Ok(TrainConfig {
        kernel: Kernel::gaussian(p.f64("gamma")?),
        stage1: Stage1Config {
            budget: p.usize("budget")?,
            eps_rank: p.f64("eps-rank")?,
            chunk: p.usize("chunk")?,
            seed: p.u64("seed")?,
            ..Default::default()
        },
        solver: SolverOptions {
            c: p.f64("c")?,
            eps: p.f64("eps")?,
            shrinking: !p.flag("no-shrinking"),
            seed: p.u64("seed")?,
            ..Default::default()
        },
        threads: p.usize("threads")?,
        compact_pairs: true,
    })
}

fn train_args() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("data", "", "training data (LIBSVM format; or use --shards)"),
        ArgSpec::opt(
            "block-budget-mb",
            "",
            "out-of-core mode: stream feature blocks under this byte budget \
             (0 = one block; any budget yields a byte-identical model)",
        ),
        ArgSpec::opt(
            "shards",
            "",
            "out-of-core mode: directory of LIBSVM shard files (see 'lpdsvm split') \
             read blockwise instead of --data",
        ),
        ArgSpec::opt("budget", "512", "landmark budget B"),
        ArgSpec::opt("c", "1.0", "regularisation C"),
        ArgSpec::opt("gamma", "0.05", "Gaussian kernel bandwidth"),
        ArgSpec::opt("eps", "0.01", "KKT stopping tolerance"),
        ArgSpec::opt("eps-rank", "1e-6", "eigenvalue truncation threshold"),
        ArgSpec::opt("chunk", "256", "stage-1 chunk rows"),
        ArgSpec::opt("threads", "0", "worker threads (0 = auto)"),
        ArgSpec::opt("seed", "42", "RNG seed"),
        ArgSpec::flag("no-shrinking", "disable shrinking"),
        ArgSpec::opt(
            "checkpoint",
            "",
            "crash-safe checkpoint directory; a re-run with the same arguments \
             resumes from it bit-identically",
        ),
        ArgSpec::opt(
            "checkpoint-every",
            "5",
            "checkpoint each solver every N epochs (with --checkpoint)",
        ),
    ]
    .into_iter()
    .chain(obs_args())
    .collect()
}

/// Whether the run asked for the out-of-core data plane (either flag
/// engages it; `--block-budget-mb 0` means "one block", the reference
/// run for the byte-identity contract).
fn streaming_requested(p: &lpdsvm::util::cli::Parsed) -> bool {
    !p.str("block-budget-mb").is_empty() || !p.str("shards").is_empty()
}

fn block_budget_bytes(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<usize> {
    let s = p.str("block-budget-mb");
    if s.is_empty() {
        return Ok(0);
    }
    let mb: usize = s
        .parse()
        .map_err(|e| anyhow::anyhow!("--block-budget-mb: bad value '{s}': {e}"))?;
    Ok(mb * 1024 * 1024)
}

/// The `--data` path, required whenever `--shards` doesn't replace it.
fn require_data(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<&str> {
    let d = p.str("data");
    anyhow::ensure!(!d.is_empty(), "--data is required (or --shards in out-of-core mode)");
    Ok(d)
}

/// Resolve the out-of-core source: a sharded on-disk reader when
/// `--shards` is given, otherwise the in-memory dataset behind the
/// [`DataSource`] seam. Exactly one of the returns is `Some`.
fn open_source(
    p: &lpdsvm::util::cli::Parsed,
) -> anyhow::Result<(Option<ShardedSource>, Option<Dataset>)> {
    anyhow::ensure!(
        p.str("backend") == "native",
        "out-of-core mode (--block-budget-mb/--shards) supports the native backend only"
    );
    let shards = p.str("shards");
    if !shards.is_empty() {
        anyhow::ensure!(
            p.str("data").is_empty(),
            "--data and --shards are mutually exclusive"
        );
        Ok((Some(ShardedSource::open(Path::new(shards))?), None))
    } else {
        Ok((None, Some(load_data(require_data(p)?)?)))
    }
}

/// Enforce `--max-rss-mb`: fail the run if the kernel's peak-RSS
/// high-water mark exceeded the cap. 0 = off. This is the bounded-memory
/// contract the CI smoke asserts.
fn check_max_rss(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<()> {
    let cap_mb = p.usize("max-rss-mb")?;
    if cap_mb == 0 {
        return Ok(());
    }
    match lpdsvm::util::mem::peak_rss_bytes() {
        Some(peak) => {
            println!(
                "peak RSS {:.1} MiB (cap {cap_mb} MiB)",
                peak as f64 / (1024.0 * 1024.0)
            );
            anyhow::ensure!(
                peak <= cap_mb as u64 * 1024 * 1024,
                "peak RSS {:.1} MiB exceeded --max-rss-mb {cap_mb}",
                peak as f64 / (1024.0 * 1024.0)
            );
            Ok(())
        }
        None => {
            lpdsvm::log_warn!("train", "--max-rss-mb: peak RSS unavailable on this platform");
            Ok(())
        }
    }
}

/// Build the optional checkpoint context from `--checkpoint` /
/// `--checkpoint-every` (shared by train, cv, and grid).
fn ckpt_from(p: &lpdsvm::util::cli::Parsed) -> anyhow::Result<Option<CheckpointCtx>> {
    let dir = p.str("checkpoint");
    if dir.is_empty() {
        return Ok(None);
    }
    let every = p.usize("checkpoint-every")?;
    anyhow::ensure!(every > 0, "--checkpoint-every must be >= 1");
    Ok(Some(CheckpointCtx::new(Path::new(dir), every)?))
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let mut specs = train_args();
    specs.push(ArgSpec::req("model-out", "path to save the trained model"));
    specs.push(ArgSpec::opt(
        "max-rss-mb",
        "0",
        "fail if the process's peak RSS exceeds this many MiB (0 = off; \
         the bounded-memory assertion for out-of-core runs)",
    ));
    specs.extend(backend_args());
    let p = parse("train", "Train an LPD-SVM model", &specs, args)?;
    obs_setup(&p)?;
    let cfg = train_cfg_from(&p)?;
    let ckpt = ckpt_from(&p)?;
    let mut clock = StageClock::new();
    let (model, train_err) = if streaming_requested(&p) {
        let budget = block_budget_bytes(&p)?;
        let (sharded, resident) = open_source(&p)?;
        let memory = resident.as_ref().map(MemorySource::new);
        let source: &dyn DataSource = match (&sharded, &memory) {
            (Some(s), _) => s,
            (None, Some(m)) => m,
            (None, None) => unreachable!("open_source returns one of the two"),
        };
        let model = train_streaming(source, &cfg, budget, &mut clock, ckpt.as_ref())?;
        model_io::save(&model, Path::new(p.str("model-out")))?;
        let err = streaming_error_rate(source, &model, None, budget)?;
        (model, err)
    } else {
        let data = load_data(require_data(&p)?)?;
        let model = with_backend(p.str("backend"), |b| {
            train_with_backend_ckpt(&data, &cfg, b, &mut clock, ckpt.as_ref())
        })?;
        model_io::save(&model, Path::new(p.str("model-out")))?;
        let err = model.error_rate(&data.x, &data.labels)?;
        (model, err)
    };
    let mut t = Table::new("training summary", &["stage", "seconds"]);
    for (k, v) in clock.entries() {
        t.row(&[k, Table::secs(v)]);
    }
    t.print();
    println!(
        "rank={} heads={} train_error={}% model={}",
        model.factor.rank,
        model.heads.len(),
        Table::pct(train_err),
        p.str("model-out")
    );
    check_max_rss(&p)?;
    obs_finish(&p)?;
    Ok(())
}

fn cmd_split(args: &[String]) -> anyhow::Result<()> {
    let specs: Vec<ArgSpec> = vec![
        ArgSpec::req("data", "input LIBSVM file"),
        ArgSpec::req("out-dir", "directory for the shard files"),
        ArgSpec::opt("parts", "8", "number of shards"),
    ]
    .into_iter()
    .chain(obs_args())
    .collect();
    let p = parse(
        "split",
        "Shard a LIBSVM file into block files for out-of-core training",
        &specs,
        args,
    )?;
    obs_setup(&p)?;
    let summary = libsvm::split_shards(
        Path::new(p.str("data")),
        Path::new(p.str("out-dir")),
        p.usize("parts")?,
    )?;
    let mut t = Table::new("label histogram", &["raw label", "rows"]);
    for (label, count) in &summary.label_counts {
        t.row(&[label.to_string(), count.to_string()]);
    }
    t.print();
    println!(
        "wrote {} rows into {} shards (<= {} rows each) under {} — \
         concatenating the shards reproduces the input byte for byte",
        summary.rows,
        summary.shard_rows.len(),
        summary.shard_rows.iter().max().copied().unwrap_or(0),
        p.str("out-dir")
    );
    obs_finish(&p)?;
    Ok(())
}

fn cmd_predict(args: &[String]) -> anyhow::Result<()> {
    let mut specs = vec![
        ArgSpec::req("model", "saved model path"),
        ArgSpec::req("data", "input data (LIBSVM format; labels used for error)"),
        ArgSpec::opt("out", "", "write predictions to this file (one per line)"),
    ];
    specs.extend(backend_args());
    specs.extend(obs_args());
    let p = parse("predict", "Predict with a saved model", &specs, args)?;
    obs_setup(&p)?;
    let model = model_io::load(Path::new(p.str("model")))?;
    let data = load_data(p.str("data"))?;
    let t0 = std::time::Instant::now();
    let preds = with_backend(p.str("backend"), |b| {
        model.predict_with_backend(&data.x, b)
    })?;
    let secs = t0.elapsed().as_secs_f64();
    let err = error_rate(&preds, &data.labels);
    println!(
        "predicted {} points in {} s — error {}%",
        preds.len(),
        Table::secs(secs),
        Table::pct(err)
    );
    if !p.str("out").is_empty() {
        let text: String = preds.iter().map(|c| format!("{c}\n")).collect();
        std::fs::write(p.str("out"), text)?;
    }
    obs_finish(&p)?;
    Ok(())
}

fn cmd_cv(args: &[String]) -> anyhow::Result<()> {
    let mut specs = train_args();
    specs.push(ArgSpec::opt("folds", "5", "number of CV folds"));
    let p = parse("cv", "k-fold cross validation (shared stage 1)", &specs, args)?;
    obs_setup(&p)?;
    let cfg = train_cfg_from(&p)?;
    let cv = CvConfig {
        folds: p.usize("folds")?,
        seed: p.u64("seed")?,
    };
    let ckpt = ckpt_from(&p)?;
    let r = if streaming_requested(&p) {
        let budget = block_budget_bytes(&p)?;
        let (sharded, resident) = open_source(&p)?;
        let memory = resident.as_ref().map(MemorySource::new);
        let source: &dyn DataSource = match (&sharded, &memory) {
            (Some(s), _) => s,
            (None, Some(m)) => m,
            (None, None) => unreachable!("open_source returns one of the two"),
        };
        cross_validate_streaming(source, &cfg, &cv, budget, ckpt.as_ref().map(|c| (c, "")))?
    } else {
        let data = load_data(require_data(&p)?)?;
        cross_validate_ckpt(&data, &cfg, &cv, ckpt.as_ref())?
    };
    let mut t = Table::new("cross-validation", &["fold", "error %"]);
    for (i, e) in r.fold_errors.iter().enumerate() {
        t.row(&[i.to_string(), Table::pct(*e)]);
    }
    t.print();
    println!(
        "mean error {}% over {} binary problems in {} s",
        Table::pct(r.mean_error),
        r.n_binary_problems,
        Table::secs(r.total_secs)
    );
    obs_finish(&p)?;
    Ok(())
}

fn cmd_grid(args: &[String]) -> anyhow::Result<()> {
    let mut specs = train_args();
    specs.push(ArgSpec::opt("folds", "5", "CV folds per grid point"));
    specs.push(ArgSpec::opt(
        "c-grid",
        "0.25,1,4,16,64",
        "comma-separated C values",
    ));
    specs.push(ArgSpec::opt(
        "gamma-grid",
        "0.01,0.05,0.2",
        "comma-separated gamma values",
    ));
    specs.push(ArgSpec::flag("no-warm-start", "disable warm starts along C"));
    let p = parse("grid", "Grid search with CV + warm starts", &specs, args)?;
    obs_setup(&p)?;
    let base = train_cfg_from(&p)?;
    let parse_grid = |s: &str| -> anyhow::Result<Vec<f64>> {
        s.split(',')
            .map(|x| x.trim().parse::<f64>().map_err(Into::into))
            .collect()
    };
    let grid = GridConfig {
        c_values: parse_grid(p.str("c-grid"))?,
        gamma_values: parse_grid(p.str("gamma-grid"))?,
        cv_folds: p.usize("folds")?,
        seed: p.u64("seed")?,
        warm_start: !p.flag("no-warm-start"),
    };
    let ckpt = ckpt_from(&p)?;
    if streaming_requested(&p) {
        return grid_streaming(&p, &base, &grid, ckpt.as_ref());
    }
    let data = load_data(require_data(&p)?)?;
    let r = grid_search_ckpt(&data, &base, &grid, ckpt.as_ref())?;
    let mut t = Table::new("grid search", &["gamma", "C", "cv error %"]);
    for pt in &r.points {
        t.row(&[
            format!("{:e}", pt.gamma),
            pt.c.to_string(),
            Table::pct(pt.cv.mean_error),
        ]);
    }
    t.print();
    println!(
        "best: gamma={:e} C={} error {}%  |  {} binary problems, total {} s, {} s/problem (stage1 {} s)",
        r.best_gamma,
        r.best_c,
        Table::pct(r.best_error),
        r.n_binary_problems,
        Table::secs(r.total_secs),
        Table::secs(r.secs_per_problem()),
        Table::secs(r.stage1_secs),
    );
    obs_finish(&p)?;
    Ok(())
}

/// Out-of-core grid search: a plain double loop over (γ, C) running
/// streaming CV per cell. No cross-cell warm starts (they would need the
/// per-pair α resident across cells — the opposite of the fixed-memory
/// contract); stage 1 is still recomputed only once per γ *within* each
/// cell's CV. Checkpoints use the classic per-cell tag prefixes.
fn grid_streaming(
    p: &lpdsvm::util::cli::Parsed,
    base: &TrainConfig,
    grid: &GridConfig,
    ckpt: Option<&CheckpointCtx>,
) -> anyhow::Result<()> {
    if grid.warm_start {
        lpdsvm::log_warn!(
            "grid",
            "out-of-core grid search runs without warm starts along C \
             (duals are not kept resident between cells)"
        );
    }
    let budget = block_budget_bytes(p)?;
    let (sharded, resident) = open_source(p)?;
    let memory = resident.as_ref().map(MemorySource::new);
    let source: &dyn DataSource = match (&sharded, &memory) {
        (Some(s), _) => s,
        (None, Some(m)) => m,
        (None, None) => unreachable!("open_source returns one of the two"),
    };
    let cv = CvConfig {
        folds: grid.cv_folds,
        seed: grid.seed,
    };
    let t0 = Instant::now();
    let mut t = Table::new("grid search (out-of-core)", &["gamma", "C", "cv error %"]);
    let mut best = (f64::NAN, f64::NAN, f64::INFINITY);
    let mut n_binary = 0usize;
    for (gi, &gamma) in grid.gamma_values.iter().enumerate() {
        for (ci, &c) in grid.c_values.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.kernel = Kernel::gaussian(gamma);
            cfg.solver.c = c;
            let prefix = format!("cell_g{gi}_c{ci}_");
            let r = cross_validate_streaming(
                source,
                &cfg,
                &cv,
                budget,
                ckpt.map(|ctx| (ctx, prefix.as_str())),
            )?;
            n_binary += r.n_binary_problems;
            t.row(&[format!("{gamma:e}"), c.to_string(), Table::pct(r.mean_error)]);
            if r.mean_error < best.2 {
                best = (gamma, c, r.mean_error);
            }
        }
    }
    t.print();
    let (bg, bc, be) = best;
    println!(
        "best: gamma={bg:e} C={bc} error {}%  |  {n_binary} binary problems, total {} s",
        Table::pct(be),
        Table::secs(t0.elapsed().as_secs_f64()),
    );
    obs_finish(p)?;
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let mut specs = vec![
        ArgSpec::opt("model", "", "saved model path (default: train a synthetic model)"),
        ArgSpec::opt("dataset", "adult", "synthetic workload: paper dataset analogue"),
        ArgSpec::opt("scale", "0.005", "synthetic workload scale (fraction of paper n)"),
        ArgSpec::opt("budget", "128", "landmark budget B for the synthetic model"),
        ArgSpec::opt("seed", "42", "RNG seed"),
        ArgSpec::opt(
            "requests",
            "10000",
            "requests submitted by the load generator (0 = none; needs --listen)",
        ),
        ArgSpec::opt("rate", "0", "open-loop arrival rate, req/s (0 = as fast as possible)"),
        ArgSpec::opt("max-batch", "256", "dispatch a batch at this many queued requests"),
        ArgSpec::opt("max-wait-us", "2000", "dispatch a partial batch after this wait (µs)"),
        ArgSpec::opt("workers", "0", "scoring worker threads (0 = auto)"),
        ArgSpec::opt(
            "max-queue",
            "0",
            "admission control: bound the request queue (0 = unbounded)",
        ),
        ArgSpec::opt(
            "shed-policy",
            "reject-newest",
            "full-queue policy: reject-newest | drop-expired",
        ),
        ArgSpec::opt(
            "tenants",
            "1",
            "serve the model under this many names; with --saturate, tenants beyond \
             'default' run closed-loop cold probes proving cross-model isolation",
        ),
        ArgSpec::opt(
            "model-weight",
            "",
            "comma-separated NAME=W scheduler weights (e.g. default=4,tenant1=1)",
        ),
        ArgSpec::flag(
            "no-supervise",
            "disable worker supervision (panicked workers stay dead; debugging aid)",
        ),
        ArgSpec::opt(
            "quarantine-after",
            "3",
            "quarantine a model after this many consecutive batch panics (0 = never)",
        ),
        ArgSpec::opt(
            "quarantine-cooldown-ms",
            "250",
            "cooldown before a quarantined model gets a half-open probe batch",
        ),
        ArgSpec::opt(
            "retries",
            "0",
            "load generator: retry retryable failures up to this many rounds \
             (exponential backoff with jitter)",
        ),
        ArgSpec::opt(
            "retry-budget",
            "0",
            "load generator: total resubmissions allowed across all retry rounds \
             (0 = one per original request)",
        ),
        ArgSpec::opt("listen", "", "serve over HTTP on this address (e.g. 127.0.0.1:8080)"),
        ArgSpec::opt(
            "max-connections",
            "1024",
            "HTTP connection cap; over-limit accepts get 503 (0 = unbounded)",
        ),
        ArgSpec::opt(
            "io-model",
            "threads",
            "HTTP connection plane: threads (one per connection) | evented \
             (single epoll event loop, Linux only)",
        ),
        ArgSpec::opt(
            "idle-timeout-ms",
            "30000",
            "drop HTTP connections idle (or trickling one request phase) past this",
        ),
        ArgSpec::flag(
            "saturate",
            "overload mode: unpaced arrivals against a bounded queue; fails unless the engine shed load",
        ),
        ArgSpec::flag("compare", "also time a naive per-request predict() loop"),
    ];
    specs.extend(backend_args());
    specs.extend(obs_args());
    let p = parse(
        "serve",
        "Serve a model through the micro-batching engine (optionally over HTTP) under synthetic load",
        &specs,
        args,
    )?;
    obs_setup(&p)?;

    // Workload rows always come from a synthetic paper-analogue dataset;
    // the served model is either loaded from disk (it must match the
    // dataset's feature dimension) or trained on that same dataset.
    let dataset = PaperDataset::from_name(p.str("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", p.str("dataset")))?;
    let spec = dataset.spec(p.f64("scale")?, p.u64("seed")?);
    let data = spec.synth.generate();

    let registry = Arc::new(ModelRegistry::new());
    if p.str("model").is_empty() {
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: p.usize("budget")?,
                seed: p.u64("seed")?,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut clock = StageClock::new();
        let model = with_backend(p.str("backend"), |b| {
            train_with_backend(&data, &cfg, b, &mut clock)
        })?;
        lpdsvm::log_info!(
            "serve",
            "trained synthetic '{}' model: n={} rank={} heads={}",
            data.name,
            data.len(),
            model.factor.rank,
            model.heads.len()
        );
        registry.insert("default", model);
    } else {
        registry.load_file("default", Path::new(p.str("model")))?;
        lpdsvm::log_info!("serve", "loaded model from {}", p.str("model"));
    }
    let model = registry.get("default").expect("just registered");
    anyhow::ensure!(
        model.factor.landmarks.cols == data.dim(),
        "model dimension {} does not match workload dimension {}",
        model.factor.landmarks.cols,
        data.dim()
    );

    let saturate = p.flag("saturate");
    // Multi-tenant mode: register the same model under extra names, so
    // the fair scheduler has real tenants to arbitrate between. Only
    // meaningful under --saturate (the isolation drill); the single-
    // tenant path below is untouched.
    let tenants = p.usize("tenants")?;
    anyhow::ensure!(tenants >= 1, "--tenants must be >= 1");
    anyhow::ensure!(
        tenants == 1 || saturate,
        "--tenants > 1 is the cross-model isolation drill; combine it with --saturate"
    );
    let tenant_names: Vec<String> = (1..tenants).map(|i| format!("tenant{i}")).collect();
    for name in &tenant_names {
        registry.insert_arc(name, Arc::clone(model.model()));
    }
    for spec in p.str("model-weight").split(',').filter(|s| !s.is_empty()) {
        let (name, w) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--model-weight entries are NAME=W, got '{spec}'"))?;
        let (name, w) = (name.trim(), w.trim());
        let weight: u64 = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--model-weight {name}: bad weight '{w}': {e}"))?;
        anyhow::ensure!(weight >= 1, "--model-weight {name}: weight must be >= 1");
        anyhow::ensure!(
            registry.contains(name),
            "--model-weight names an unregistered model '{name}'"
        );
        let mut cfg: ModelServeConfig = registry.serve_config(name);
        cfg.weight = weight;
        registry.set_serve_config(name, cfg);
    }
    let shed_policy = match p.str("shed-policy") {
        "reject-newest" => ShedPolicy::RejectNewest,
        "drop-expired" => ShedPolicy::DropExpired,
        other => anyhow::bail!("unknown --shed-policy '{other}' (reject-newest | drop-expired)"),
    };
    let mut max_queue = p.usize("max-queue")?;
    let workers = p.usize("workers")?;
    if saturate && max_queue == 0 {
        // Saturation needs a traffic boundary to push against; default to
        // one full batch per worker of headroom.
        let effective_workers = if workers == 0 {
            lpdsvm::util::threads::default_threads().max(1)
        } else {
            workers
        };
        max_queue = (p.usize("max-batch")?.max(1) * effective_workers).max(1);
        lpdsvm::log_warn!(
            "serve",
            "--saturate without --max-queue: bounding the queue at {max_queue}"
        );
    }
    let cfg = ServeConfig {
        max_batch: p.usize("max-batch")?,
        max_wait: Duration::from_micros(p.u64("max-wait-us")?),
        workers,
        max_queue,
        shed_policy,
        supervise: !p.flag("no-supervise"),
        panic_quarantine_after: p.u64("quarantine-after")? as u32,
        quarantine_cooldown: Duration::from_millis(p.u64("quarantine-cooldown-ms")?),
    };
    let provider = provider_for(p.str("backend"))?;
    let engine = Arc::new(ServeEngine::start_with_provider(
        Arc::clone(&registry),
        cfg,
        provider,
    ));
    lpdsvm::log_info!(
        "serve",
        "engine up: max_batch={} max_wait={}µs workers={} max_queue={} shed_policy={:?} backend={}",
        engine.config().max_batch,
        engine.config().max_wait.as_micros(),
        engine.config().workers,
        engine.config().max_queue,
        engine.config().shed_policy,
        p.str("backend"),
    );

    let io_model = IoModel::from_name(p.str("io-model")).ok_or_else(|| {
        anyhow::anyhow!("unknown --io-model '{}' (threads | evented)", p.str("io-model"))
    })?;
    let http = if p.str("listen").is_empty() {
        None
    } else {
        let server = HttpServer::bind_with_opts(
            Arc::clone(&engine),
            p.str("listen"),
            HttpOptions {
                max_connections: p.usize("max-connections")?,
                io_model,
                idle_timeout: Duration::from_millis(p.u64("idle-timeout-ms")?.max(1)),
            },
        )?;
        lpdsvm::log_info!(
            "serve",
            "http front-end on {} ({:?} io) — POST /v1/models/default:predict, GET /v1/models /metrics /healthz",
            server.addr(),
            io_model
        );
        Some(server)
    };

    // Open-loop generator: arrival times are scheduled up front and never
    // depend on completions, so queueing delay shows up as latency (the
    // honest way to load-test a service) rather than throttling arrivals.
    let n_requests = p.usize("requests")?;
    if n_requests == 0 {
        anyhow::ensure!(
            http.is_some(),
            "--requests 0 disables the load generator; combine it with --listen"
        );
        anyhow::ensure!(!saturate, "--saturate needs the load generator (--requests > 0)");
        lpdsvm::log_info!("serve", "no load generator (--requests 0); serving until killed");
        loop {
            std::thread::park();
        }
    }
    let rate = if saturate {
        if p.f64("rate")? > 0.0 {
            lpdsvm::log_warn!(
                "serve",
                "--saturate ignores --rate: arrivals are unpaced to outrun the workers"
            );
        }
        0.0
    } else {
        p.f64("rate")?
    };
    let rows: Vec<Vec<(u32, f32)>> = (0..data.len()).map(|i| data.x.row_entries(i)).collect();

    // Cold-tenant probes (multi-tenant saturate only): one closed-loop
    // submitter per extra tenant — at most one request in flight, so the
    // tenant's own sub-queue never fills and any shed it suffers can only
    // come from the hot tenant leaking into it. Starvation-freedom shows
    // up as completed probes; a fairness bug shows up as probe sheds.
    use std::sync::atomic::{AtomicBool, Ordering};
    let stop_probes = Arc::new(AtomicBool::new(false));
    let probes: Vec<_> = tenant_names
        .iter()
        .map(|name| {
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop_probes);
            let name = name.clone();
            let row = rows[0].clone();
            std::thread::spawn(move || {
                let (mut completed, mut failed) = (0u64, 0u64);
                while !stop.load(Ordering::Acquire) {
                    match engine.submit(&name, &row).wait() {
                        Ok(_) => completed += 1,
                        Err(_) => failed += 1,
                    }
                }
                (name, completed, failed)
            })
        })
        .collect();

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        if rate > 0.0 {
            let due = t0 + Duration::from_secs_f64(i as f64 / rate);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        tickets.push(engine.submit("default", &rows[i % rows.len()]));
    }
    let mut errors = 0usize;
    let mut mismatches = 0usize;
    let mut retryable: Vec<usize> = Vec::new();
    for (i, t) in tickets.iter().enumerate() {
        match t.wait() {
            Ok(pred) => {
                if pred.label != data.labels[i % rows.len()] {
                    mismatches += 1;
                }
            }
            Err(e) => {
                errors += 1;
                if e.is_retryable() {
                    retryable.push(i);
                }
            }
        }
    }
    let elapsed = t0.elapsed();
    stop_probes.store(true, Ordering::Release);
    let probe_results: Vec<(String, u64, u64)> = probes
        .into_iter()
        .map(|h| h.join().expect("probe thread"))
        .collect();
    let served = n_requests - errors;

    // Retry rounds: resubmit retryable failures (sheds, quarantines,
    // no-healthy-workers) with capped exponential backoff + jitter. The
    // retry budget bounds total resubmissions so an unhealthy engine
    // cannot turn the generator into a retry storm.
    let max_retries = p.usize("retries")?;
    let first_pass_retryable = retryable.len();
    let mut recovered = 0usize;
    let mut retry_submitted = 0usize;
    if max_retries > 0 && !retryable.is_empty() {
        let mut budget = p.usize("retry-budget")?;
        if budget == 0 {
            budget = n_requests;
        }
        let mut jitter = lpdsvm::util::rng::Rng::new(p.u64("seed")? ^ 0x7e7e_7e7e);
        for round in 1..=max_retries {
            if retryable.is_empty() || budget == 0 {
                break;
            }
            // 1ms, 2ms, 4ms, ... capped at 100ms, each ±50% jittered.
            let base_us = (1000u64 << (round - 1).min(7)).min(100_000);
            let wait_us = base_us / 2 + jitter.next_u64() % base_us;
            std::thread::sleep(Duration::from_micros(wait_us));
            let take = retryable.len().min(budget);
            budget -= take;
            retry_submitted += take;
            let this_round: Vec<usize> = retryable.drain(..take).collect();
            let resubmits: Vec<(usize, _)> = this_round
                .iter()
                .map(|&i| (i, engine.submit("default", &rows[i % rows.len()])))
                .collect();
            let mut still_failing = Vec::new();
            for (i, t) in resubmits {
                match t.wait() {
                    Ok(pred) => {
                        recovered += 1;
                        if pred.label != data.labels[i % rows.len()] {
                            mismatches += 1;
                        }
                    }
                    Err(e) if e.is_retryable() => still_failing.push(i),
                    Err(_) => {}
                }
            }
            retryable.splice(0..0, still_failing);
        }
    }
    engine.metrics().table(elapsed).print();
    println!(
        "served {n_requests} requests in {} s — {:.0} req/s, {} failed, label error {}%",
        Table::secs(elapsed.as_secs_f64()),
        n_requests as f64 / elapsed.as_secs_f64(),
        errors,
        // Error rate over the requests that actually got a prediction.
        Table::pct(mismatches as f64 / (served + recovered).max(1) as f64)
    );
    if max_retries > 0 {
        let total_elapsed = t0.elapsed().as_secs_f64();
        let eventually_served = served + recovered;
        println!(
            "retry: recovered {recovered}/{first_pass_retryable} retryable failures in \
             {retry_submitted} resubmission(s) — goodput after retry {:.0} req/s \
             ({eventually_served}/{n_requests} eventually served)",
            eventually_served as f64 / total_elapsed
        );
    }
    if saturate {
        let m = engine.metrics();
        // Relaxed: post-run snapshot of monotone telemetry counters;
        // every worker has already been joined by shutdown() above.
        let rejected_full = m.rejected_full.load(Ordering::Relaxed);
        let shed_expired = m.shed_expired.load(Ordering::Relaxed);
        let queue_max = m.queue_depth_max.load(Ordering::Relaxed);
        println!(
            "saturation: rejected_full={rejected_full} shed_expired={shed_expired} \
             queue_depth_max={queue_max} (cap {max_queue})"
        );
        // `max_queue` bounds each tenant's sub-queue individually, so the
        // aggregate depth across tenants can reach `tenants × max_queue`.
        let depth_bound = (max_queue * tenants) as u64;
        anyhow::ensure!(
            queue_max <= depth_bound,
            "queue grew past its bound: {queue_max} > {depth_bound}"
        );
        // The CI smoke relies on this: a clean exit from --saturate means
        // the shedding path actually ran.
        anyhow::ensure!(
            rejected_full + shed_expired > 0,
            "saturate mode never overflowed the {max_queue}-slot queue — \
             raise --requests or lower --max-queue/--workers"
        );
        // Cross-model isolation: the saturating hot tenant must be the
        // only one shedding. Every cold probe ran closed-loop, so its
        // sub-queue could never fill on its own — a nonzero shed count
        // here means the scheduler let the hot backlog spill over.
        for (name, completed, failed) in &probe_results {
            let bucket = m.model(name);
            let shed = bucket.shed();
            println!(
                "tenant '{name}': completed={completed} failed={failed} shed={shed} \
                 p99={:.3}ms",
                bucket.latency_us.quantile(0.99) as f64 / 1e3
            );
            anyhow::ensure!(
                shed == 0,
                "cold tenant '{name}' was shed {shed} times while 'default' saturated — \
                 per-model isolation violated"
            );
            anyhow::ensure!(
                *completed > 0,
                "cold tenant '{name}' starved: no probe completed while 'default' saturated"
            );
        }
        if !probe_results.is_empty() {
            let hot = m.model("default");
            anyhow::ensure!(
                hot.shed() > 0,
                "the hot tenant never shed — the overload did not saturate its sub-queue"
            );
            println!(
                "cross-model isolation: hot tenant shed {}, {} cold tenant(s) shed 0",
                hot.shed(),
                probe_results.len()
            );
        }
    }
    if let Some(server) = http {
        server.shutdown();
    }
    engine.shutdown();

    if p.flag("compare") && saturate {
        lpdsvm::log_warn!(
            "serve",
            "--compare is meaningless under --saturate (most requests shed); skipping"
        );
    } else if p.flag("compare") && rate > 0.0 {
        // With paced arrivals the elapsed window measures the arrival
        // rate, not engine capacity — a speedup number would be noise.
        lpdsvm::log_warn!(
            "serve",
            "--compare needs unpaced arrivals (--rate 0); skipping the naive comparison"
        );
    } else if p.flag("compare") {
        // Naive baseline: one blocking predict per request, no batching,
        // no parallelism — what the repo offered before this subsystem.
        // Same backend as the engine, so the speedup isolates batching.
        let t1 = Instant::now();
        with_backend(p.str("backend"), |b| {
            for i in 0..n_requests {
                let x = SparseMatrix::from_rows(data.dim(), &[rows[i % rows.len()].clone()]);
                let _ = model.predict_with_backend(&x, b)?;
            }
            Ok(())
        })?;
        let naive = t1.elapsed();
        let naive_rps = n_requests as f64 / naive.as_secs_f64();
        let engine_rps = n_requests as f64 / elapsed.as_secs_f64();
        println!(
            "naive per-request loop: {} s — {:.0} req/s → batched engine speedup {:.1}×",
            Table::secs(naive.as_secs_f64()),
            naive_rps,
            engine_rps / naive_rps
        );
    }
    obs_finish(&p)?;
    Ok(())
}

fn cmd_info(args: &[String]) -> anyhow::Result<()> {
    let specs = vec![ArgSpec::flag("artifacts", "also compile every artifact")];
    let p = parse("info", "Show runtime / artifact information", &specs, args)?;
    println!("lpdsvm {} — three-layer rust+JAX+Pallas build", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", lpdsvm::util::threads::default_threads());
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            let mut t = Table::new("artifacts", &["name", "m", "b", "p", "file"]);
            for a in rt.artifacts() {
                t.row(&[
                    a.name.clone(),
                    a.m.to_string(),
                    a.b.to_string(),
                    a.p.to_string(),
                    a.file.clone(),
                ]);
            }
            t.print();
            if p.flag("artifacts") {
                for a in rt.artifacts() {
                    let t0 = std::time::Instant::now();
                    rt.executable(a)?;
                    println!("compiled {} in {:.2}s", a.name, t0.elapsed().as_secs_f64());
                }
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

/// `lpdsvm lint` — run the in-repo invariant lint engine (see
/// `lpdsvm::analysis`) over the crate sources and exit nonzero if any
/// finding survives the pragma filter. CI runs this on every push.
fn cmd_lint(args: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        ArgSpec::opt(
            "root",
            ".",
            "repo or crate root to lint (must contain rust/src or src)",
        ),
        ArgSpec::opt("out", "", "also write the findings to this file (one per line)"),
        ArgSpec::flag("list-rules", "print the rule catalog and exit"),
    ];
    let p = parse("lint", "Statically enforce the crate's invariant contracts", &specs, args)?;
    if p.flag("list-rules") {
        for (name, desc) in lpdsvm::analysis::rules::RULE_NAMES {
            println!("{name:<28} {desc}");
        }
        return Ok(());
    }
    let root = Path::new(p.str("root"));
    let findings = lpdsvm::analysis::run_lint(root).map_err(|e| anyhow::anyhow!(e))?;
    for f in &findings {
        println!("{f}");
    }
    let out = p.str("out");
    if !out.is_empty() {
        let body: String = findings.iter().map(|f| format!("{f}\n")).collect();
        std::fs::write(out, body)?;
    }
    anyhow::ensure!(
        findings.is_empty(),
        "lint: {} finding(s) — fix them or add a reviewed `// lint: allow(rule)` pragma",
        findings.len()
    );
    println!(
        "lint: clean ({} rules over {})",
        lpdsvm::analysis::rules::RULE_NAMES.len(),
        root.display()
    );
    Ok(())
}
