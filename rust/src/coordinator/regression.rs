//! Regression (ε-SVR) entry point — the paper's §2 notes the decision
//! function is "directly suitable for regression tasks"; this wires the
//! SVR dual solver (`solver::svr`) to stage 1 exactly as classification.

use crate::data::sparse::SparseMatrix;
use crate::kernel::Kernel;
use crate::lowrank::factor::{NativeBackend, Stage1Backend};
use crate::lowrank::{LowRankFactor, Stage1Config};
use crate::solver::svr::{solve_svr, SvrOptions, SvrSolution};
use crate::util::timer::StageClock;

/// Configuration for one SVR training run.
#[derive(Clone, Debug)]
pub struct SvrTrainConfig {
    pub kernel: Kernel,
    pub stage1: Stage1Config,
    pub svr: SvrOptions,
}

impl Default for SvrTrainConfig {
    fn default() -> Self {
        SvrTrainConfig {
            kernel: Kernel::gaussian(0.1),
            stage1: Stage1Config::default(),
            svr: SvrOptions::default(),
        }
    }
}

/// A trained regression model.
pub struct SvrModel {
    pub factor: LowRankFactor,
    pub w: Vec<f32>,
    pub solution: SvrSolution,
}

impl SvrModel {
    /// Predict targets for new inputs.
    pub fn predict(&self, x: &SparseMatrix) -> anyhow::Result<Vec<f32>> {
        self.predict_with_backend(x, &NativeBackend::default())
    }

    pub fn predict_with_backend(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
    ) -> anyhow::Result<Vec<f32>> {
        let g = self.factor.transform(x, backend, 1024)?;
        Ok(g.matvec(&self.w))
    }

    /// Mean absolute error against targets.
    pub fn mae(&self, x: &SparseMatrix, y: &[f32]) -> anyhow::Result<f64> {
        let preds = self.predict(x)?;
        anyhow::ensure!(preds.len() == y.len());
        Ok(preds
            .iter()
            .zip(y)
            .map(|(p, t)| (p - t).abs() as f64)
            .sum::<f64>()
            / y.len().max(1) as f64)
    }
}

/// Train ε-SVR: stage 1 (shared with classification), then the SVR dual.
pub fn train_svr(
    x: &SparseMatrix,
    y: &[f32],
    cfg: &SvrTrainConfig,
) -> anyhow::Result<SvrModel> {
    anyhow::ensure!(x.rows == y.len(), "targets/rows mismatch");
    anyhow::ensure!(x.rows > 0, "empty dataset");
    let mut clock = StageClock::new();
    let backend = NativeBackend::with_threads(cfg.stage1.effective_threads());
    let factor = LowRankFactor::compute(x, cfg.kernel, &cfg.stage1, &backend, &mut clock)?;
    let solution = solve_svr(&factor.g, y, &cfg.svr);
    Ok(SvrModel {
        w: solution.w.clone(),
        factor,
        solution,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn learns_nonlinear_function_end_to_end() {
        // y = x₀² − x₁, not linear in input space.
        let mut rng = Rng::new(4);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..500 {
            let a = rng.range_f64(-1.5, 1.5) as f32;
            let b = rng.range_f64(-1.5, 1.5) as f32;
            rows.push(vec![(0u32, a), (1, b)]);
            y.push(a * a - b);
        }
        let x = SparseMatrix::from_rows(2, &rows);
        let cfg = SvrTrainConfig {
            kernel: Kernel::gaussian(1.0),
            stage1: Stage1Config {
                budget: 80,
                ..Default::default()
            },
            svr: SvrOptions {
                c: 10.0,
                epsilon_tube: 0.02,
                max_epochs: 2000,
                ..Default::default()
            },
        };
        let model = train_svr(&x, &y, &cfg).unwrap();
        let mae = model.mae(&x, &y).unwrap();
        assert!(mae < 0.08, "MAE {mae}");
    }

    #[test]
    fn generalises_to_fresh_points() {
        let mut rng = Rng::new(8);
        let make = |rng: &mut Rng, n: usize| {
            let mut rows = Vec::new();
            let mut y = Vec::new();
            for _ in 0..n {
                let a = rng.range_f64(-1.0, 1.0) as f32;
                rows.push(vec![(0u32, a)]);
                y.push((3.0 * a).sin());
            }
            (SparseMatrix::from_rows(1, &rows), y)
        };
        let (x_train, y_train) = make(&mut rng, 400);
        let (x_test, y_test) = make(&mut rng, 100);
        let cfg = SvrTrainConfig {
            kernel: Kernel::gaussian(4.0),
            stage1: Stage1Config {
                budget: 60,
                ..Default::default()
            },
            svr: SvrOptions {
                c: 20.0,
                epsilon_tube: 0.01,
                max_epochs: 3000,
                ..Default::default()
            },
        };
        let model = train_svr(&x_train, &y_train, &cfg).unwrap();
        let mae = model.mae(&x_test, &y_test).unwrap();
        assert!(mae < 0.1, "test MAE {mae}");
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let x = SparseMatrix::from_rows(1, &[vec![(0u32, 1.0)]]);
        assert!(train_svr(&x, &[1.0, 2.0], &SvrTrainConfig::default()).is_err());
    }
}
