//! Top-level training entry points (single model, fixed hyperparameters).

use crate::data::block::DataSource;
use crate::data::dataset::Dataset;
use crate::kernel::Kernel;
use crate::lowrank::factor::NativeBackend;
use crate::lowrank::stream::StreamFactor;
use crate::lowrank::{LowRankFactor, Stage1Backend, Stage1Config};
use crate::model::multiclass::{error_rate, BinaryHead, MulticlassModel};
use crate::model::ModelKind;
use crate::solver::{solve_blockwise, BlockProblem, SolverOptions};
use crate::util::threads;
use crate::util::timer::StageClock;

/// Configuration for one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub kernel: Kernel,
    pub stage1: Stage1Config,
    pub solver: SolverOptions,
    /// Worker threads, honored end to end: pair-parallel training, the
    /// stage-1 compute backbone (unless `stage1.threads` pins its own
    /// count), and the native backend's row-banded GEMM/kernel blocks.
    /// 0 = auto (`LPDSVM_THREADS` or all cores).
    pub threads: usize,
    /// Copy each OVO pair's rows into a contiguous matrix before solving
    /// (cache locality; see `coordinator::ovo`).
    pub compact_pairs: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            kernel: Kernel::gaussian(0.1),
            stage1: Stage1Config::default(),
            solver: SolverOptions::default(),
            threads: 0,
            compact_pairs: true,
        }
    }
}

impl TrainConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            threads::default_threads()
        } else {
            self.threads
        }
    }
}

/// Train with the native (pure-Rust) stage-1 backend, its row-band
/// parallelism sized from [`TrainConfig::effective_threads`].
pub fn train(data: &Dataset, cfg: &TrainConfig) -> anyhow::Result<MulticlassModel> {
    let mut clock = StageClock::new();
    let backend = NativeBackend::with_threads(cfg.effective_threads());
    train_with_backend(data, cfg, &backend, &mut clock)
}

/// Train with an explicit stage-1 backend (native or PJRT accelerator),
/// accumulating per-stage wall times into `clock` under the paper's
/// figure-3 stage names ("preparation", "matrix_g", "linear_train").
pub fn train_with_backend(
    data: &Dataset,
    cfg: &TrainConfig,
    backend: &dyn Stage1Backend,
    clock: &mut StageClock,
) -> anyhow::Result<MulticlassModel> {
    train_with_backend_ckpt(data, cfg, backend, clock, None)
}

/// [`train_with_backend`] with crash-safe checkpointing: when `ckpt` is
/// set, every stage-2 solve resumes from (and records into) the
/// checkpoint directory, so a killed run re-invoked with the same
/// arguments produces a bit-identical model. Stage 1 is recomputed on
/// resume — it is deterministic from the config and not worth the disk.
pub fn train_with_backend_ckpt(
    data: &Dataset,
    cfg: &TrainConfig,
    backend: &dyn Stage1Backend,
    clock: &mut StageClock,
    ckpt: Option<&super::checkpoint::CheckpointCtx>,
) -> anyhow::Result<MulticlassModel> {
    anyhow::ensure!(!data.is_empty(), "empty dataset");
    anyhow::ensure!(data.n_classes >= 2, "need at least two classes");
    let threads = cfg.effective_threads();
    // Root span for the whole run; stage spans nest under it (StageClock
    // emits `stage.preparation` / `stage.matrix_g` / `stage.linear_train`).
    let mut span = crate::obs::Span::new("train");
    span.arg("n", data.len() as f64);
    span.arg("classes", data.n_classes as f64);
    span.arg("threads", threads as f64);
    crate::log_info!(
        "train",
        "start n={} dim={} classes={} threads={threads} budget={}",
        data.len(),
        data.x.cols,
        data.n_classes,
        cfg.stage1.budget
    );

    // Stage 1 (times itself into "preparation" + "matrix_g"). The
    // coordinator-level thread budget flows into the stage-1 backbone
    // unless the stage-1 config pins its own count.
    let stage1 = cfg.stage1.with_thread_fallback(threads);
    let factor = LowRankFactor::compute(&data.x, cfg.kernel, &stage1, backend, clock)?;

    // Stage 2.
    let subset: Vec<usize> = (0..data.len()).collect();
    let (heads, kind) = clock.time("linear_train", || -> anyhow::Result<_> {
        if data.n_classes == 2 {
            let (head, _) = super::ovo::train_pair(
                &factor.g,
                &data.labels,
                &subset,
                0,
                1,
                &cfg.solver,
                false, // binary uses all rows; compaction buys nothing
                None,
                ckpt.map(|c| (c, "pair_0_1")),
            )?;
            Ok((vec![head], ModelKind::Binary))
        } else {
            let pairs = data.class_pairs();
            let (heads, _) = super::ovo::train_all_pairs(
                &factor.g,
                &data.labels,
                &subset,
                &pairs,
                &cfg.solver,
                threads,
                cfg.compact_pairs,
                None,
                ckpt.map(|c| (c, "")),
            )?;
            Ok((
                heads,
                ModelKind::OneVsOne {
                    n_classes: data.n_classes,
                },
            ))
        }
    })?;

    span.arg("rank", factor.rank as f64);
    span.arg("heads", heads.len() as f64);
    crate::log_info!(
        "train",
        "done rank={} heads={} total_s={:.3}",
        factor.rank,
        heads.len(),
        clock.total().as_secs_f64()
    );
    Ok(MulticlassModel {
        factor,
        heads,
        kind,
    })
}

/// Train one blockwise binary subproblem for the pair `(a, b)` over
/// `include` rows (ascending global ids; `None` = all rows). The
/// counterpart of [`crate::coordinator::ovo::train_pair`] for the
/// out-of-core path: same row selection, same label convention
/// (class `b` ⇒ +1), same per-pair seed de-correlation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn train_pair_streaming(
    source: &dyn DataSource,
    factor: &StreamFactor,
    include: Option<&[usize]>,
    a: u32,
    b: u32,
    opts: &SolverOptions,
    budget_bytes: usize,
    backend: NativeBackend,
    ckpt: Option<(&super::checkpoint::CheckpointCtx, &str)>,
) -> anyhow::Result<BinaryHead> {
    let labels = source.labels();
    let pick = |i: usize| labels[i] == a || labels[i] == b;
    let rows: Vec<usize> = match include {
        Some(idx) => idx.iter().copied().filter(|&i| pick(i)).collect(),
        None => (0..labels.len()).filter(|&i| pick(i)).collect(),
    };
    let y: Vec<f32> = rows.iter().map(|&i| if labels[i] == b { 1.0 } else { -1.0 }).collect();
    let mut local_opts = opts.clone();
    local_opts.seed = opts.seed ^ ((a as u64) << 32 | b as u64);
    let p = BlockProblem::new(source, factor, rows, y, budget_bytes, backend);
    let sol = match ckpt {
        Some((ctx, tag)) => ctx.solve_blockwise(tag, &p, &local_opts)?,
        None => solve_blockwise(&p, &local_opts)?,
    };
    Ok(BinaryHead {
        pair: (a, b),
        w: sol.w,
        objective: sol.objective,
        converged: sol.converged,
        sv_count: sol.sv_count,
        steps: sol.steps,
    })
}

/// Out-of-core training: stage 1 and stage 2 both stream feature blocks
/// through `source` under `budget_bytes`, never materializing `G` (or,
/// for a sharded source, the features themselves) in full. Produces a
/// model that is byte-identical across block budgets and sources; the
/// `--block-budget-mb 0` run (single block) is the reference.
///
/// Pairs are solved sequentially — the data plane owns the memory
/// budget, and `cfg.threads` parallelism lives *inside* each solve's
/// per-stripe kernel/GEMM work instead of across pairs.
pub fn train_streaming(
    source: &dyn DataSource,
    cfg: &TrainConfig,
    budget_bytes: usize,
    clock: &mut StageClock,
    ckpt: Option<&super::checkpoint::CheckpointCtx>,
) -> anyhow::Result<MulticlassModel> {
    anyhow::ensure!(source.n_rows() > 0, "empty dataset");
    let n_classes = source.n_classes();
    anyhow::ensure!(n_classes >= 2, "need at least two classes");
    let threads = cfg.effective_threads();
    let backend = NativeBackend::with_threads(threads);

    let mut span = crate::obs::Span::new("train");
    span.arg("n", source.n_rows() as f64);
    span.arg("classes", n_classes as f64);
    span.arg("threads", threads as f64);
    span.arg("streaming", 1.0);
    crate::log_info!(
        "train",
        "start streaming source={} n={} dim={} classes={n_classes} threads={threads} \
         budget_mb={:.1}",
        source.name(),
        source.n_rows(),
        source.n_cols(),
        budget_bytes as f64 / (1024.0 * 1024.0)
    );

    let stage1 = cfg.stage1.with_thread_fallback(threads);
    let factor = StreamFactor::compute(source, cfg.kernel, &stage1, budget_bytes, clock)?;

    let (heads, kind) = clock.time("linear_train", || -> anyhow::Result<_> {
        if n_classes == 2 {
            let head = train_pair_streaming(
                source,
                &factor,
                None,
                0,
                1,
                &cfg.solver,
                budget_bytes,
                backend,
                ckpt.map(|c| (c, "pair_0_1")),
            )?;
            Ok((vec![head], ModelKind::Binary))
        } else {
            let mut heads = Vec::with_capacity(n_classes * (n_classes - 1) / 2);
            let tags: Vec<String> = (0..n_classes as u32)
                .flat_map(|a| {
                    ((a + 1)..n_classes as u32).map(move |b| format!("pair_{a}_{b}"))
                })
                .collect();
            let mut ti = 0;
            for a in 0..n_classes as u32 {
                for b in (a + 1)..n_classes as u32 {
                    heads.push(train_pair_streaming(
                        source,
                        &factor,
                        None,
                        a,
                        b,
                        &cfg.solver,
                        budget_bytes,
                        backend,
                        ckpt.map(|c| (c, tags[ti].as_str())),
                    )?);
                    ti += 1;
                }
            }
            Ok((heads, ModelKind::OneVsOne { n_classes }))
        }
    })?;

    span.arg("rank", factor.rank as f64);
    span.arg("heads", heads.len() as f64);
    crate::log_info!(
        "train",
        "done streaming rank={} heads={} total_s={:.3}",
        factor.rank,
        heads.len(),
        clock.total().as_secs_f64()
    );
    Ok(MulticlassModel { factor: factor.to_model_factor(), heads, kind })
}

/// Classification error of `model` over `source`, streaming feature
/// blocks under `budget_bytes` — evaluation never holds more than one
/// block of features (plus one stripe of `G` rows) resident. `include`
/// restricts scoring to those ascending global row ids (`None` = all).
pub fn streaming_error_rate(
    source: &dyn DataSource,
    model: &MulticlassModel,
    include: Option<&[usize]>,
    budget_bytes: usize,
) -> anyhow::Result<f64> {
    let labels = source.labels();
    let n_scored = include.map_or(labels.len(), |idx| idx.len());
    anyhow::ensure!(n_scored > 0, "error_rate: empty input (0 rows)");
    let backend = NativeBackend::default();
    let w_mat = model.weight_matrix();
    let mask = include.map(|idx| {
        let mut m = vec![false; source.n_rows()];
        for &i in idx {
            m[i] = true;
        }
        m
    });
    let mut preds = Vec::with_capacity(n_scored);
    let mut truth = Vec::with_capacity(n_scored);
    source.for_each_block(budget_bytes, mask.as_deref(), &mut |blk| {
        for (_, s, e) in blk.stripes() {
            let g = backend.g_chunk(
                blk.x,
                &blk.local[s..e],
                &model.factor.landmarks,
                &model.factor.landmark_sq,
                &model.factor.whiten,
                &model.factor.kernel,
            )?;
            preds.extend(model.predict_with_weights(&g, &w_mat));
            truth.extend(blk.rows[s..e].iter().map(|&i| labels[i]));
        }
        Ok(())
    })?;
    anyhow::ensure!(
        preds.len() == n_scored,
        "streaming evaluation scored {} of {} requested rows",
        preds.len(),
        n_scored
    );
    Ok(error_rate(&preds, &truth))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;

    #[test]
    fn binary_end_to_end() {
        let spec = PaperDataset::Adult.spec(0.02, 3);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                ..Default::default()
            },
            ..Default::default()
        };
        let model = train(&data, &cfg).unwrap();
        assert_eq!(model.kind, ModelKind::Binary);
        let err = model.error_rate(&data.x, &data.labels).unwrap();
        assert!(err < 0.25, "train error {err}");
    }

    #[test]
    fn multiclass_end_to_end() {
        let spec = crate::data::synth::SynthSpec {
            name: "mc".into(),
            n: 400,
            p: 12,
            n_classes: 5,
            sep: 6.0,
            latent: 4,
            noise: 1.0,
            style: crate::data::synth::FeatureStyle::Dense,
            seed: 9,
        };
        let data = spec.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.05),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            ..Default::default()
        };
        let model = train(&data, &cfg).unwrap();
        assert_eq!(model.heads.len(), 10); // C(5,2)
        let err = model.error_rate(&data.x, &data.labels).unwrap();
        assert!(err < 0.15, "train error {err}");
    }

    #[test]
    fn stage_clock_has_all_three_stages() {
        let spec = PaperDataset::Adult.spec(0.005, 4);
        let data = spec.synth.generate();
        let cfg = TrainConfig::default();
        let mut clock = StageClock::new();
        train_with_backend(&data, &cfg, &NativeBackend::default(), &mut clock).unwrap();
        for stage in ["preparation", "matrix_g", "linear_train"] {
            assert!(clock.secs(stage) > 0.0, "missing stage {stage}");
        }
    }

    #[test]
    fn rejects_empty_and_single_class() {
        let x = crate::data::sparse::SparseMatrix::from_rows(2, &[vec![(0, 1.0)]]);
        let ds = Dataset::new("one", x, vec![0], 1);
        assert!(train(&ds, &TrainConfig::default()).is_err());
    }

    #[test]
    fn streaming_binary_is_budget_invariant_and_accurate() {
        let spec = PaperDataset::Adult.spec(0.02, 3);
        let data = spec.synth.generate();
        let src = crate::data::block::MemorySource::new(&data);
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config { budget: 64, ..Default::default() },
            solver: SolverOptions { c: spec.c, ..Default::default() },
            ..Default::default()
        };
        let reference =
            train_streaming(&src, &cfg, 0, &mut StageClock::new(), None).unwrap();
        let blocked =
            train_streaming(&src, &cfg, 48_000, &mut StageClock::new(), None).unwrap();
        assert_eq!(reference.heads.len(), 1);
        assert_eq!(reference.heads[0].w, blocked.heads[0].w);
        assert_eq!(reference.heads[0].steps, blocked.heads[0].steps);
        let err = streaming_error_rate(&src, &reference, None, 48_000).unwrap();
        assert!(err < 0.25, "streaming train error {err}");
        // Streaming evaluation agrees with the resident predictor.
        let resident = reference.error_rate(&data.x, &data.labels).unwrap();
        assert_eq!(err, resident);
    }

    #[test]
    fn streaming_multiclass_is_budget_invariant() {
        let spec = crate::data::synth::SynthSpec {
            name: "mc".into(),
            n: 360,
            p: 10,
            n_classes: 3,
            sep: 6.0,
            latent: 4,
            noise: 1.0,
            style: crate::data::synth::FeatureStyle::Dense,
            seed: 17,
        };
        let data = spec.generate();
        let src = crate::data::block::MemorySource::new(&data);
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.05),
            stage1: Stage1Config { budget: 48, ..Default::default() },
            ..Default::default()
        };
        let reference =
            train_streaming(&src, &cfg, 0, &mut StageClock::new(), None).unwrap();
        let blocked =
            train_streaming(&src, &cfg, 20_000, &mut StageClock::new(), None).unwrap();
        assert_eq!(reference.heads.len(), 3); // C(3,2)
        for (a, b) in reference.heads.iter().zip(&blocked.heads) {
            assert_eq!(a.pair, b.pair);
            assert_eq!(a.w, b.w, "pair {:?}", a.pair);
        }
        let err = streaming_error_rate(&src, &reference, None, 20_000).unwrap();
        assert!(err < 0.15, "streaming train error {err}");
    }
}
