//! Top-level training entry points (single model, fixed hyperparameters).

use crate::data::dataset::Dataset;
use crate::kernel::Kernel;
use crate::lowrank::factor::NativeBackend;
use crate::lowrank::{LowRankFactor, Stage1Backend, Stage1Config};
use crate::model::multiclass::MulticlassModel;
use crate::model::ModelKind;
use crate::solver::SolverOptions;
use crate::util::threads;
use crate::util::timer::StageClock;

/// Configuration for one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub kernel: Kernel,
    pub stage1: Stage1Config,
    pub solver: SolverOptions,
    /// Worker threads, honored end to end: pair-parallel training, the
    /// stage-1 compute backbone (unless `stage1.threads` pins its own
    /// count), and the native backend's row-banded GEMM/kernel blocks.
    /// 0 = auto (`LPDSVM_THREADS` or all cores).
    pub threads: usize,
    /// Copy each OVO pair's rows into a contiguous matrix before solving
    /// (cache locality; see `coordinator::ovo`).
    pub compact_pairs: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            kernel: Kernel::gaussian(0.1),
            stage1: Stage1Config::default(),
            solver: SolverOptions::default(),
            threads: 0,
            compact_pairs: true,
        }
    }
}

impl TrainConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            threads::default_threads()
        } else {
            self.threads
        }
    }
}

/// Train with the native (pure-Rust) stage-1 backend, its row-band
/// parallelism sized from [`TrainConfig::effective_threads`].
pub fn train(data: &Dataset, cfg: &TrainConfig) -> anyhow::Result<MulticlassModel> {
    let mut clock = StageClock::new();
    let backend = NativeBackend::with_threads(cfg.effective_threads());
    train_with_backend(data, cfg, &backend, &mut clock)
}

/// Train with an explicit stage-1 backend (native or PJRT accelerator),
/// accumulating per-stage wall times into `clock` under the paper's
/// figure-3 stage names ("preparation", "matrix_g", "linear_train").
pub fn train_with_backend(
    data: &Dataset,
    cfg: &TrainConfig,
    backend: &dyn Stage1Backend,
    clock: &mut StageClock,
) -> anyhow::Result<MulticlassModel> {
    train_with_backend_ckpt(data, cfg, backend, clock, None)
}

/// [`train_with_backend`] with crash-safe checkpointing: when `ckpt` is
/// set, every stage-2 solve resumes from (and records into) the
/// checkpoint directory, so a killed run re-invoked with the same
/// arguments produces a bit-identical model. Stage 1 is recomputed on
/// resume — it is deterministic from the config and not worth the disk.
pub fn train_with_backend_ckpt(
    data: &Dataset,
    cfg: &TrainConfig,
    backend: &dyn Stage1Backend,
    clock: &mut StageClock,
    ckpt: Option<&super::checkpoint::CheckpointCtx>,
) -> anyhow::Result<MulticlassModel> {
    anyhow::ensure!(!data.is_empty(), "empty dataset");
    anyhow::ensure!(data.n_classes >= 2, "need at least two classes");
    let threads = cfg.effective_threads();
    // Root span for the whole run; stage spans nest under it (StageClock
    // emits `stage.preparation` / `stage.matrix_g` / `stage.linear_train`).
    let mut span = crate::obs::Span::new("train");
    span.arg("n", data.len() as f64);
    span.arg("classes", data.n_classes as f64);
    span.arg("threads", threads as f64);
    crate::log_info!(
        "train",
        "start n={} dim={} classes={} threads={threads} budget={}",
        data.len(),
        data.x.cols,
        data.n_classes,
        cfg.stage1.budget
    );

    // Stage 1 (times itself into "preparation" + "matrix_g"). The
    // coordinator-level thread budget flows into the stage-1 backbone
    // unless the stage-1 config pins its own count.
    let stage1 = cfg.stage1.with_thread_fallback(threads);
    let factor = LowRankFactor::compute(&data.x, cfg.kernel, &stage1, backend, clock)?;

    // Stage 2.
    let subset: Vec<usize> = (0..data.len()).collect();
    let (heads, kind) = clock.time("linear_train", || -> anyhow::Result<_> {
        if data.n_classes == 2 {
            let (head, _) = super::ovo::train_pair(
                &factor.g,
                &data.labels,
                &subset,
                0,
                1,
                &cfg.solver,
                false, // binary uses all rows; compaction buys nothing
                None,
                ckpt.map(|c| (c, "pair_0_1")),
            )?;
            Ok((vec![head], ModelKind::Binary))
        } else {
            let pairs = data.class_pairs();
            let (heads, _) = super::ovo::train_all_pairs(
                &factor.g,
                &data.labels,
                &subset,
                &pairs,
                &cfg.solver,
                threads,
                cfg.compact_pairs,
                None,
                ckpt.map(|c| (c, "")),
            )?;
            Ok((
                heads,
                ModelKind::OneVsOne {
                    n_classes: data.n_classes,
                },
            ))
        }
    })?;

    span.arg("rank", factor.rank as f64);
    span.arg("heads", heads.len() as f64);
    crate::log_info!(
        "train",
        "done rank={} heads={} total_s={:.3}",
        factor.rank,
        heads.len(),
        clock.total().as_secs_f64()
    );
    Ok(MulticlassModel {
        factor,
        heads,
        kind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;

    #[test]
    fn binary_end_to_end() {
        let spec = PaperDataset::Adult.spec(0.02, 3);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                ..Default::default()
            },
            ..Default::default()
        };
        let model = train(&data, &cfg).unwrap();
        assert_eq!(model.kind, ModelKind::Binary);
        let err = model.error_rate(&data.x, &data.labels).unwrap();
        assert!(err < 0.25, "train error {err}");
    }

    #[test]
    fn multiclass_end_to_end() {
        let spec = crate::data::synth::SynthSpec {
            name: "mc".into(),
            n: 400,
            p: 12,
            n_classes: 5,
            sep: 6.0,
            latent: 4,
            noise: 1.0,
            style: crate::data::synth::FeatureStyle::Dense,
            seed: 9,
        };
        let data = spec.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.05),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            ..Default::default()
        };
        let model = train(&data, &cfg).unwrap();
        assert_eq!(model.heads.len(), 10); // C(5,2)
        let err = model.error_rate(&data.x, &data.labels).unwrap();
        assert!(err < 0.15, "train error {err}");
    }

    #[test]
    fn stage_clock_has_all_three_stages() {
        let spec = PaperDataset::Adult.spec(0.005, 4);
        let data = spec.synth.generate();
        let cfg = TrainConfig::default();
        let mut clock = StageClock::new();
        train_with_backend(&data, &cfg, &NativeBackend::default(), &mut clock).unwrap();
        for stage in ["preparation", "matrix_g", "linear_train"] {
            assert!(clock.secs(stage) > 0.0, "missing stage {stage}");
        }
    }

    #[test]
    fn rejects_empty_and_single_class() {
        let x = crate::data::sparse::SparseMatrix::from_rows(2, &[vec![(0, 1.0)]]);
        let ds = Dataset::new("one", x, vec![0], 1);
        assert!(train(&ds, &TrainConfig::default()).is_err());
    }
}
