//! Cross-validation with a shared stage 1.
//!
//! Paper §4 ("Cross Validation, Parameter Tuning, and Multi-Class
//! Training"): the feature-space representation is fixed ONCE on the whole
//! dataset, `G` is precomputed, and only then is the data subdivided into
//! folds — every fold reuses the same `G`, paying stage 1 exactly once.
//! (The paper notes the slight optimistic bias this can introduce and
//! argues it is immaterial for parameter tuning; see footnote 4.)

use crate::coordinator::ovo::{self, WarmStore};
use crate::coordinator::train::TrainConfig;
use crate::data::dataset::Dataset;
use crate::data::folds::Folds;
use crate::linalg::Mat;
use crate::lowrank::LowRankFactor;
use crate::model::multiclass::{error_rate, BinaryHead};
use crate::model::ModelKind;
use crate::model::MulticlassModel;
use crate::util::rng::Rng;
use crate::util::timer::StageClock;

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvConfig {
    pub folds: usize,
    pub seed: u64,
}

impl Default for CvConfig {
    fn default() -> Self {
        CvConfig { folds: 5, seed: 7 }
    }
}

/// Result of one cross-validation run.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub fold_errors: Vec<f64>,
    pub mean_error: f64,
    /// Number of binary problems trained (folds × pairs).
    pub n_binary_problems: usize,
    pub total_secs: f64,
}

/// Run k-fold CV for a fixed hyperparameter setting, computing stage 1
/// once on the full dataset.
pub fn cross_validate(
    data: &Dataset,
    cfg: &TrainConfig,
    cv: &CvConfig,
) -> anyhow::Result<CvResult> {
    cross_validate_ckpt(data, cfg, cv, None)
}

/// [`cross_validate`] with crash-safe checkpointing of every fold's pair
/// solves (stage 1 and the fold assignment are deterministic and
/// recomputed on resume).
pub fn cross_validate_ckpt(
    data: &Dataset,
    cfg: &TrainConfig,
    cv: &CvConfig,
    ckpt: Option<&super::checkpoint::CheckpointCtx>,
) -> anyhow::Result<CvResult> {
    let mut clock = StageClock::new();
    let threads = cfg.effective_threads();
    let stage1 = cfg.stage1.with_thread_fallback(threads);
    let factor = LowRankFactor::compute(
        &data.x,
        cfg.kernel,
        &stage1,
        &crate::lowrank::factor::NativeBackend::with_threads(threads),
        &mut clock,
    )?;
    let folds = Folds::stratified(&data.labels, cv.folds, &mut Rng::new(cv.seed));
    cross_validate_shared_ckpt(data, &factor, &folds, cfg, None, ckpt.map(|c| (c, "")))
        .map(|(r, _)| r)
}

/// CV over a *precomputed* factor and fold assignment — the entry the grid
/// search uses so stage 1 and folds are shared across all (C, γ) points.
/// `warm` optionally carries per-(fold, pair) dual variables from a
/// previous (smaller-C) run; the updated store is returned.
pub fn cross_validate_shared(
    data: &Dataset,
    factor: &LowRankFactor,
    folds: &Folds,
    cfg: &TrainConfig,
    warm: Option<&Vec<WarmStore>>,
) -> anyhow::Result<(CvResult, Vec<WarmStore>)> {
    cross_validate_shared_ckpt(data, factor, folds, cfg, warm, None)
}

/// [`cross_validate_shared`] with crash-safe checkpointing: `ckpt`
/// carries a context plus a tag prefix, and fold `f`'s pair solves
/// checkpoint under `{prefix}fold{f}_pair_{a}_{b}`.
pub fn cross_validate_shared_ckpt(
    data: &Dataset,
    factor: &LowRankFactor,
    folds: &Folds,
    cfg: &TrainConfig,
    warm: Option<&Vec<WarmStore>>,
    ckpt: Option<(&super::checkpoint::CheckpointCtx, &str)>,
) -> anyhow::Result<(CvResult, Vec<WarmStore>)> {
    let t0 = std::time::Instant::now();
    let pairs = if data.n_classes == 2 {
        vec![(0u32, 1u32)]
    } else {
        data.class_pairs()
    };
    let threads = cfg.effective_threads();

    let mut fold_errors = Vec::with_capacity(folds.k);
    let mut stores: Vec<WarmStore> = Vec::with_capacity(folds.k);
    for f in 0..folds.k {
        let (train_idx, val_idx) = folds.split(f);
        // `Folds::stratified` can no longer produce an empty fold (the
        // round-robin offset is carried across classes), but `Folds` is a
        // plain pub struct — guard against hand-built or future
        // assignments so the failure names the fold instead of surfacing
        // as a NaN error rate or an empty-problem panic deep in training.
        anyhow::ensure!(
            !val_idx.is_empty(),
            "cross-validation fold {f} has an empty validation set \
             ({} folds over {} points; lower k or provide more data per class)",
            folds.k,
            data.len()
        );
        anyhow::ensure!(
            !train_idx.is_empty(),
            "cross-validation fold {f} has an empty training set ({} folds over {} points)",
            folds.k,
            data.len()
        );
        let mut fold_span = crate::obs::Span::new("cv.fold");
        fold_span.arg("fold", f as f64);
        fold_span.arg("train_rows", train_idx.len() as f64);
        fold_span.arg("val_rows", val_idx.len() as f64);
        let fold_ckpt = ckpt.map(|(ctx, prefix)| (ctx, format!("{prefix}fold{f}_")));
        let (heads, store) = ovo::train_all_pairs(
            &factor.g,
            &data.labels,
            &train_idx,
            &pairs,
            &cfg.solver,
            threads,
            cfg.compact_pairs,
            warm.map(|w| &w[f]),
            fold_ckpt.as_ref().map(|(c, p)| (*c, p.as_str())),
        )?;
        let err = evaluate_heads(&factor.g, &heads, data, &val_idx);
        fold_span.arg("error", err);
        crate::log_debug!("cv", "fold={f} error={err:.4} pairs={}", pairs.len());
        fold_errors.push(err);
        stores.push(store);
    }

    let mean_error = fold_errors.iter().sum::<f64>() / fold_errors.len().max(1) as f64;
    Ok((
        CvResult {
            n_binary_problems: folds.k * pairs.len(),
            mean_error,
            fold_errors,
            total_secs: t0.elapsed().as_secs_f64(),
        },
        stores,
    ))
}

/// Out-of-core k-fold CV: stage 1 streams once over the full source
/// (the paper's shared-stage-1 scheme carries over unchanged), folds are
/// stratified on the label vector — which a [`crate::data::ShardedSource`]
/// reads in a cheap first pass, no features resident — and every fold's
/// pair solves and validation scoring stream blocks under `budget_bytes`.
///
/// With `ckpt` (a context plus a tag prefix), fold `f`'s pair `(a, b)`
/// checkpoints under `{prefix}fold{f}_pair_{a}_{b}`, mirroring the
/// classic path's tags; the grid search supplies per-cell prefixes.
pub fn cross_validate_streaming(
    source: &dyn crate::data::block::DataSource,
    cfg: &TrainConfig,
    cv: &CvConfig,
    budget_bytes: usize,
    ckpt: Option<(&super::checkpoint::CheckpointCtx, &str)>,
) -> anyhow::Result<CvResult> {
    use crate::coordinator::train::{streaming_error_rate, train_pair_streaming};
    use crate::lowrank::StreamFactor;

    let t0 = std::time::Instant::now();
    let n_classes = source.n_classes();
    anyhow::ensure!(n_classes >= 2, "need at least two classes");
    let pairs: Vec<(u32, u32)> = if n_classes == 2 {
        vec![(0u32, 1u32)]
    } else {
        let c = n_classes as u32;
        (0..c).flat_map(|a| ((a + 1)..c).map(move |b| (a, b))).collect()
    };
    let threads = cfg.effective_threads();
    let backend = crate::lowrank::factor::NativeBackend::with_threads(threads);
    let stage1 = cfg.stage1.with_thread_fallback(threads);
    let mut clock = StageClock::new();
    let factor = StreamFactor::compute(source, cfg.kernel, &stage1, budget_bytes, &mut clock)?;
    let folds = Folds::stratified(source.labels(), cv.folds, &mut Rng::new(cv.seed));

    let mut fold_errors = Vec::with_capacity(folds.k);
    for f in 0..folds.k {
        let (train_idx, val_idx) = folds.split(f);
        anyhow::ensure!(
            !val_idx.is_empty(),
            "cross-validation fold {f} has an empty validation set \
             ({} folds over {} points; lower k or provide more data per class)",
            folds.k,
            source.n_rows()
        );
        anyhow::ensure!(
            !train_idx.is_empty(),
            "cross-validation fold {f} has an empty training set ({} folds over {} points)",
            folds.k,
            source.n_rows()
        );
        let mut fold_span = crate::obs::Span::new("cv.fold");
        fold_span.arg("fold", f as f64);
        fold_span.arg("train_rows", train_idx.len() as f64);
        fold_span.arg("val_rows", val_idx.len() as f64);
        fold_span.arg("streaming", 1.0);
        let mut heads = Vec::with_capacity(pairs.len());
        for &(a, b) in &pairs {
            let tag = ckpt.map(|(_, prefix)| format!("{prefix}fold{f}_pair_{a}_{b}"));
            heads.push(train_pair_streaming(
                source,
                &factor,
                Some(&train_idx),
                a,
                b,
                &cfg.solver,
                budget_bytes,
                backend,
                ckpt.map(|(c, _)| (c, tag.as_deref().unwrap_or(""))),
            )?);
        }
        let kind = if n_classes == 2 {
            ModelKind::Binary
        } else {
            ModelKind::OneVsOne { n_classes }
        };
        let model = MulticlassModel { factor: factor.to_model_factor(), heads, kind };
        let err = streaming_error_rate(source, &model, Some(&val_idx), budget_bytes)?;
        fold_span.arg("error", err);
        crate::log_debug!("cv", "fold={f} error={err:.4} pairs={} (streaming)", pairs.len());
        fold_errors.push(err);
    }

    let mean_error = fold_errors.iter().sum::<f64>() / fold_errors.len().max(1) as f64;
    Ok(CvResult {
        n_binary_problems: folds.k * pairs.len(),
        mean_error,
        fold_errors,
        total_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Evaluate a set of heads on validation rows using the shared `G`.
fn evaluate_heads(g: &Mat, heads: &[BinaryHead], data: &Dataset, val_idx: &[usize]) -> f64 {
    let kind = if data.n_classes == 2 {
        ModelKind::Binary
    } else {
        ModelKind::OneVsOne {
            n_classes: data.n_classes,
        }
    };
    // Borrow trick: build a lightweight model around clones of the small
    // parts; G rows are selected, not copied wholesale.
    let g_val = g.select_rows(val_idx);
    let model = MulticlassModel {
        factor: LowRankFactor {
            g: Mat::zeros(0, g.cols),
            landmarks: Mat::zeros(0, 0),
            landmark_sq: vec![],
            whiten: Mat::zeros(0, 0),
            rank: g.cols,
            eigenvalues: vec![],
            kernel: crate::kernel::Kernel::Linear,
            landmark_idx: vec![],
        },
        heads: heads.to_vec(),
        kind,
    };
    let preds = model.predict_from_features(&g_val);
    let truth: Vec<u32> = val_idx.iter().map(|&i| data.labels[i]).collect();
    error_rate(&preds, &truth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{FeatureStyle, PaperDataset, SynthSpec};
    use crate::kernel::Kernel;
    use crate::lowrank::Stage1Config;
    use crate::solver::SolverOptions;

    #[test]
    fn cv_binary_reasonable_error() {
        let spec = PaperDataset::Adult.spec(0.02, 11);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            solver: SolverOptions {
                c: spec.c,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = cross_validate(&data, &cfg, &CvConfig::default()).unwrap();
        assert_eq!(r.fold_errors.len(), 5);
        assert_eq!(r.n_binary_problems, 5);
        assert!(r.mean_error < 0.35, "cv error {}", r.mean_error);
    }

    #[test]
    fn cv_multiclass_counts_problems() {
        let data = SynthSpec {
            name: "mc".into(),
            n: 300,
            p: 10,
            n_classes: 4,
            sep: 4.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 12,
        }
        .generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.05),
            stage1: Stage1Config {
                budget: 48,
                ..Default::default()
            },
            ..Default::default()
        };
        let cv = CvConfig { folds: 3, seed: 1 };
        let r = cross_validate(&data, &cfg, &cv).unwrap();
        assert_eq!(r.n_binary_problems, 3 * 6);
        assert!(r.mean_error < 0.25, "cv error {}", r.mean_error);
    }

    #[test]
    fn empty_validation_fold_is_a_clear_error() {
        // `Folds::stratified` can no longer produce one, so hand-build an
        // assignment that leaves fold 2 empty and drive the shared-CV
        // entry point directly.
        let spec = PaperDataset::Adult.spec(0.005, 31);
        let data = spec.synth.generate();
        let cfg = TrainConfig {
            stage1: Stage1Config {
                budget: 16,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(
            &data.x,
            cfg.kernel,
            &cfg.stage1,
            &crate::lowrank::factor::NativeBackend::default(),
            &mut clock,
        )
        .unwrap();
        let assignments: Vec<u32> = (0..data.len()).map(|i| (i % 2) as u32).collect();
        let folds = Folds { assignments, k: 3 };
        let err = cross_validate_shared(&data, &factor, &folds, &cfg, None).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("fold 2") && msg.contains("empty validation"),
            "unhelpful error: {msg}"
        );
    }

    #[test]
    fn streaming_cv_is_budget_invariant_and_reasonable() {
        let spec = PaperDataset::Adult.spec(0.02, 11);
        let data = spec.synth.generate();
        let src = crate::data::block::MemorySource::new(&data);
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(spec.gamma),
            stage1: Stage1Config { budget: 48, ..Default::default() },
            solver: SolverOptions { c: spec.c, ..Default::default() },
            ..Default::default()
        };
        let cv = CvConfig { folds: 3, seed: 7 };
        let reference = cross_validate_streaming(&src, &cfg, &cv, 0, None).unwrap();
        let blocked = cross_validate_streaming(&src, &cfg, &cv, 30_000, None).unwrap();
        assert_eq!(reference.fold_errors, blocked.fold_errors);
        assert_eq!(reference.n_binary_problems, 3);
        assert!(reference.mean_error < 0.35, "cv error {}", reference.mean_error);
    }

    #[test]
    fn cv_error_worse_than_train_error_on_noisy_data() {
        // Validation error should not be (much) below training error.
        let data = SynthSpec {
            name: "noisy".into(),
            n: 400,
            p: 8,
            n_classes: 2,
            sep: 1.2,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 13,
        }
        .generate();
        let cfg = TrainConfig {
            kernel: Kernel::gaussian(0.1),
            stage1: Stage1Config {
                budget: 64,
                ..Default::default()
            },
            solver: SolverOptions {
                c: 8.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let model = crate::coordinator::train::train(&data, &cfg).unwrap();
        let train_err = model.error_rate(&data.x, &data.labels).unwrap();
        let r = cross_validate(&data, &cfg, &CvConfig::default()).unwrap();
        assert!(
            r.mean_error >= train_err - 0.02,
            "cv {} < train {}",
            r.mean_error,
            train_err
        );
    }
}
