//! Hyperparameter grid search with G-reuse and warm starts.
//!
//! Paper §5 ("Parameter Tuning and Cross-Validation"): a 10×5 grid over
//! (C, γ) with 5-fold CV trains `250·C(c,2)` binary SVMs, yet stage 1 runs
//! only once per γ (5 times total), and solvers along the ascending C path
//! are warm-started from the previous C — together yielding the ×2–×7
//! per-problem speed-ups of table 3.

use crate::coordinator::checkpoint::CheckpointCtx;
use crate::coordinator::cv::{cross_validate_shared_ckpt, CvResult};
use crate::coordinator::ovo::WarmStore;
use crate::coordinator::train::TrainConfig;
use crate::data::dataset::Dataset;
use crate::data::folds::Folds;
use crate::lowrank::factor::NativeBackend;
use crate::lowrank::LowRankFactor;
use crate::util::rng::Rng;
use crate::util::timer::StageClock;

/// Grid-search configuration.
#[derive(Clone, Debug)]
pub struct GridConfig {
    /// C values — sorted ascending internally for the warm-start path.
    pub c_values: Vec<f64>,
    /// Kernel bandwidths γ; stage 1 recomputes once per value.
    pub gamma_values: Vec<f64>,
    pub cv_folds: usize,
    pub seed: u64,
    /// Warm-start along the C path (paper behaviour). Disable for
    /// ablations.
    pub warm_start: bool,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            c_values: (0..10).map(|i| 2f64.powi(i)).collect(),
            gamma_values: vec![0.01, 0.1],
            cv_folds: 5,
            seed: 1234,
            warm_start: true,
        }
    }
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub c: f64,
    pub gamma: f64,
    pub cv: CvResult,
}

/// Full grid-search outcome.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub points: Vec<GridPoint>,
    pub best_c: f64,
    pub best_gamma: f64,
    pub best_error: f64,
    /// Total binary problems trained across the whole grid.
    pub n_binary_problems: usize,
    pub total_secs: f64,
    /// Wall time spent in stage 1 (once per γ).
    pub stage1_secs: f64,
}

impl GridResult {
    /// Seconds per binary problem — table 3's second row.
    pub fn secs_per_problem(&self) -> f64 {
        self.total_secs / self.n_binary_problems.max(1) as f64
    }
}

/// Run the grid search. `base` supplies everything except (C, γ).
pub fn grid_search(
    data: &Dataset,
    base: &TrainConfig,
    grid: &GridConfig,
) -> anyhow::Result<GridResult> {
    grid_search_ckpt(data, base, grid, None)
}

/// [`grid_search`] with a crash-safe per-cell completion journal. Each
/// finished grid cell `(γ index, C index)` records its `CvResult` and
/// warm stores under `cell_g{gi}_c{ci}.cell.ckpt`; a killed sweep
/// re-invoked with the same arguments skips completed cells (their
/// journaled warm stores keep the C-path warm-start chain bit-identical)
/// and resumes mid-solve inside the first unfinished cell.
pub fn grid_search_ckpt(
    data: &Dataset,
    base: &TrainConfig,
    grid: &GridConfig,
    ckpt: Option<&CheckpointCtx>,
) -> anyhow::Result<GridResult> {
    anyhow::ensure!(!grid.c_values.is_empty() && !grid.gamma_values.is_empty());
    let t0 = std::time::Instant::now();
    let mut c_values = grid.c_values.clone();
    c_values.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Folds are fixed across the entire grid so results are comparable and
    // warm starts stay aligned.
    let folds = Folds::stratified(&data.labels, grid.cv_folds, &mut Rng::new(grid.seed));

    let mut points = Vec::new();
    let mut n_problems = 0usize;
    let mut stage1_secs = 0.0f64;

    // The grid's thread budget drives the stage-1 backbone too.
    let threads = base.effective_threads();
    let stage1_cfg = base.stage1.with_thread_fallback(threads);
    let backend = NativeBackend::with_threads(threads);

    for (gi, &gamma) in grid.gamma_values.iter().enumerate() {
        // Stage 1: once per γ, shared by all C values and folds.
        let kernel = base.kernel.with_gamma(gamma);
        let mut clock = StageClock::new();
        let factor =
            LowRankFactor::compute(&data.x, kernel, &stage1_cfg, &backend, &mut clock)?;
        stage1_secs += clock.total().as_secs_f64();

        let mut warm: Option<Vec<WarmStore>> = None;
        for (ci, &c) in c_values.iter().enumerate() {
            let cell_tag = format!("cell_g{gi}_c{ci}");
            if let Some(ctx) = ckpt {
                if let Some((cv, stores)) = ctx.load_cell(&cell_tag)? {
                    crate::log_info!(
                        "grid",
                        "cell γ={gamma} C={c} already complete in journal, skipping"
                    );
                    n_problems += cv.n_binary_problems;
                    points.push(GridPoint { c, gamma, cv });
                    warm = Some(stores);
                    continue;
                }
            }
            let mut cfg = base.clone();
            cfg.kernel = kernel;
            cfg.solver.c = c;
            let cell_prefix = format!("{cell_tag}_");
            let (cv, stores) = cross_validate_shared_ckpt(
                data,
                &factor,
                &folds,
                &cfg,
                if grid.warm_start { warm.as_ref() } else { None },
                ckpt.map(|ctx| (ctx, cell_prefix.as_str())),
            )?;
            if let Some(ctx) = ckpt {
                // Journal the finished cell, then drop its per-solve
                // checkpoints — the journal supersedes them. A journal
                // write failure only degrades resumability.
                if let Err(e) = ctx.store_cell(&cell_tag, &cv, &stores) {
                    crate::log_warn!("grid", "cell journal write failed for {cell_tag}: {e}");
                } else {
                    ctx.gc_prefix(&cell_prefix);
                }
            }
            n_problems += cv.n_binary_problems;
            points.push(GridPoint { c, gamma, cv });
            warm = Some(stores);
        }
    }

    let best = points
        .iter()
        .min_by(|a, b| a.cv.mean_error.partial_cmp(&b.cv.mean_error).unwrap())
        .expect("non-empty grid");
    Ok(GridResult {
        best_c: best.c,
        best_gamma: best.gamma,
        best_error: best.cv.mean_error,
        points,
        n_binary_problems: n_problems,
        total_secs: t0.elapsed().as_secs_f64(),
        stage1_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::kernel::Kernel;
    use crate::lowrank::Stage1Config;
    use crate::solver::SolverOptions;

    fn base_cfg(gamma: f64) -> TrainConfig {
        TrainConfig {
            kernel: Kernel::gaussian(gamma),
            stage1: Stage1Config {
                budget: 32,
                ..Default::default()
            },
            solver: SolverOptions::default(),
            ..Default::default()
        }
    }

    #[test]
    fn grid_counts_and_best() {
        let spec = PaperDataset::Adult.spec(0.008, 17);
        let data = spec.synth.generate();
        let grid = GridConfig {
            c_values: vec![1.0, 4.0, 16.0],
            gamma_values: vec![0.02, 0.08],
            cv_folds: 3,
            seed: 5,
            warm_start: true,
        };
        let r = grid_search(&data, &base_cfg(0.05), &grid).unwrap();
        assert_eq!(r.points.len(), 6);
        assert_eq!(r.n_binary_problems, 6 * 3); // points × folds (binary)
        assert!(grid.c_values.contains(&r.best_c));
        assert!(grid.gamma_values.contains(&r.best_gamma));
        assert!(r.best_error <= r.points[0].cv.mean_error + 1e-12);
        assert!(r.secs_per_problem() > 0.0);
    }

    #[test]
    fn warm_start_does_not_change_errors_much() {
        let spec = PaperDataset::Adult.spec(0.006, 23);
        let data = spec.synth.generate();
        let grid_warm = GridConfig {
            c_values: vec![0.5, 2.0, 8.0],
            gamma_values: vec![0.05],
            cv_folds: 3,
            seed: 5,
            warm_start: true,
        };
        let grid_cold = GridConfig {
            warm_start: false,
            ..grid_warm.clone()
        };
        let rw = grid_search(&data, &base_cfg(0.05), &grid_warm).unwrap();
        let rc = grid_search(&data, &base_cfg(0.05), &grid_cold).unwrap();
        for (pw, pc) in rw.points.iter().zip(&rc.points) {
            assert!(
                (pw.cv.mean_error - pc.cv.mean_error).abs() < 0.05,
                "warm {} vs cold {} at C={}",
                pw.cv.mean_error,
                pc.cv.mean_error,
                pw.c
            );
        }
    }

    #[test]
    fn checkpointed_grid_matches_plain_and_resumes_from_journal() {
        let spec = PaperDataset::Adult.spec(0.006, 41);
        let data = spec.synth.generate();
        let grid = GridConfig {
            c_values: vec![0.5, 2.0],
            gamma_values: vec![0.05],
            cv_folds: 2,
            seed: 9,
            warm_start: true,
        };
        let plain = grid_search(&data, &base_cfg(0.05), &grid).unwrap();

        let dir = std::env::temp_dir()
            .join(format!("lpdsvm_grid_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CheckpointCtx::new(&dir, 1).unwrap();
        let first = grid_search_ckpt(&data, &base_cfg(0.05), &grid, Some(&ctx)).unwrap();
        // A re-run over the same journal must skip every cell and still
        // reproduce the identical sweep (the bit-identity contract).
        let resumed = grid_search_ckpt(&data, &base_cfg(0.05), &grid, Some(&ctx)).unwrap();
        for (a, b) in plain.points.iter().zip(&first.points) {
            assert_eq!(a.cv.fold_errors, b.cv.fold_errors, "ckpt changed results");
        }
        for (a, b) in first.points.iter().zip(&resumed.points) {
            assert_eq!(a.cv.fold_errors, b.cv.fold_errors, "journal replay diverged");
        }
        assert_eq!(first.best_c, resumed.best_c);
        assert_eq!(first.n_binary_problems, resumed.n_binary_problems);
        // Journals persist; per-solve checkpoints were garbage-collected.
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.iter().any(|n| n.ends_with(".cell.ckpt")), "{names:?}");
        assert!(
            names.iter().all(|n| n.ends_with(".cell.ckpt")),
            "stray per-solve checkpoints: {names:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stage1_runs_once_per_gamma() {
        // Indirect check: stage1_secs should not scale with |C grid|.
        let spec = PaperDataset::Adult.spec(0.004, 29);
        let data = spec.synth.generate();
        let grid_small = GridConfig {
            c_values: vec![1.0],
            gamma_values: vec![0.05],
            cv_folds: 2,
            seed: 3,
            warm_start: true,
        };
        let grid_large = GridConfig {
            c_values: vec![0.25, 0.5, 1.0, 2.0, 4.0, 8.0],
            ..grid_small.clone()
        };
        let r1 = grid_search(&data, &base_cfg(0.05), &grid_small).unwrap();
        let r6 = grid_search(&data, &base_cfg(0.05), &grid_large).unwrap();
        // 6× the C values should cost well below 6× the stage-1 time.
        assert!(
            r6.stage1_secs < r1.stage1_secs * 3.0 + 0.05,
            "stage1 {} vs {}",
            r6.stage1_secs,
            r1.stage1_secs
        );
    }
}
