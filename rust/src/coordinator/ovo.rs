//! One-versus-one multiclass training over a shared `G` matrix.
//!
//! Each class pair `(a, b)` induces a small binary problem over the rows of
//! `G` belonging to those classes. The paper trains up to ~½ million such
//! problems (1000 classes) and notes the scheme is "a welcome opportunity
//! for parallelization" — pairs are scheduled over the thread pool here.

use crate::coordinator::checkpoint::CheckpointCtx;
use crate::linalg::Mat;
use crate::model::multiclass::BinaryHead;
use crate::solver::{solve, ProblemView, Solution, SolverOptions};
use crate::util::threads::parallel_map;

/// Warm-start storage: per-pair dual variables from a previous run with
/// the same row layout (used by the grid search along the C path).
pub type WarmStore = Vec<Option<Vec<f32>>>;

/// Train one binary head for the pair `(a, b)` over the subset of
/// `subset` rows (global row ids into `g`) whose label is `a` or `b`.
///
/// `compact` copies the pair's feature rows into a dense contiguous
/// matrix before solving. For many-class problems each pair touches only
/// `2n/c` of `G`'s rows, so compaction converts scattered row access into
/// sequential scans — the same cache effect the paper credits shrinking
/// with. Returns the head and the final dual variables (for warm stores).
///
/// `ckpt` is a crash-safety context plus the solve's unique tag: the
/// solve then resumes from (and records into) that tag's checkpoint
/// files. A checkpoint read failure (corrupt file) is an error; without
/// `ckpt` the function cannot fail.
#[allow(clippy::too_many_arguments)]
pub fn train_pair(
    g: &Mat,
    labels: &[u32],
    subset: &[usize],
    a: u32,
    b: u32,
    opts: &SolverOptions,
    compact: bool,
    warm: Option<&[f32]>,
    ckpt: Option<(&CheckpointCtx, &str)>,
) -> anyhow::Result<(BinaryHead, Vec<f32>)> {
    // Deterministic row order: subset order filtered by class.
    let rows: Vec<usize> = subset
        .iter()
        .copied()
        .filter(|&i| labels[i] == a || labels[i] == b)
        .collect();
    let y: Vec<f32> = rows
        .iter()
        .map(|&i| if labels[i] == b { 1.0 } else { -1.0 })
        .collect();

    let mut local_opts = opts.clone();
    local_opts.warm_alpha = warm.map(|w| w.to_vec());
    // De-correlate pair permutations.
    local_opts.seed = opts.seed ^ ((a as u64) << 32 | b as u64);

    let run = |p: &ProblemView| -> anyhow::Result<Solution> {
        match ckpt {
            Some((ctx, tag)) => ctx.solve(tag, p, &local_opts),
            None => Ok(solve(p, &local_opts)),
        }
    };
    let sol = if compact {
        let compacted = g.select_rows(&rows);
        let local_rows: Vec<usize> = (0..rows.len()).collect();
        let p = ProblemView::new(&compacted, &local_rows, &y);
        run(&p)?
    } else {
        let p = ProblemView::new(g, &rows, &y);
        run(&p)?
    };

    let head = BinaryHead {
        pair: (a, b),
        w: sol.w,
        objective: sol.objective,
        converged: sol.converged,
        sv_count: sol.sv_count,
        steps: sol.steps,
    };
    Ok((head, sol.alpha))
}

/// Train all `c·(c−1)/2` pair heads in parallel. `pairs` fixes the job
/// order; `warm` (if given) must be aligned with it. Returns heads in pair
/// order plus the updated warm store.
///
/// `ckpt` carries a checkpoint context plus a tag *prefix*; each pair's
/// solve checkpoints under `{prefix}pair_{a}_{b}`. The context is `Sync`,
/// so pool threads checkpoint their own solves independently.
#[allow(clippy::too_many_arguments)]
pub fn train_all_pairs(
    g: &Mat,
    labels: &[u32],
    subset: &[usize],
    pairs: &[(u32, u32)],
    opts: &SolverOptions,
    threads: usize,
    compact: bool,
    warm: Option<&WarmStore>,
    ckpt: Option<(&CheckpointCtx, &str)>,
) -> anyhow::Result<(Vec<BinaryHead>, WarmStore)> {
    let results = parallel_map(pairs.len(), threads, |pi| {
        let (a, b) = pairs[pi];
        // One span per OVO job, attributed to whichever pool thread (or
        // the submitter) runs it.
        let mut span = crate::obs::Span::new("ovo.pair");
        span.arg("a", a as f64);
        span.arg("b", b as f64);
        let warm_alpha = warm.and_then(|w| w[pi].as_deref());
        let tag = ckpt.map(|(_, prefix)| format!("{prefix}pair_{a}_{b}"));
        let pair_ckpt = match (&ckpt, &tag) {
            (Some((ctx, _)), Some(t)) => Some((*ctx, t.as_str())),
            _ => None,
        };
        train_pair(g, labels, subset, a, b, opts, compact, warm_alpha, pair_ckpt)
    });
    let mut heads = Vec::with_capacity(results.len());
    let mut store: WarmStore = Vec::with_capacity(results.len());
    for result in results {
        let (head, alpha) = result?;
        heads.push(head);
        store.push(Some(alpha));
    }
    Ok((heads, store))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{FeatureStyle, SynthSpec};
    use crate::kernel::Kernel;
    use crate::lowrank::factor::NativeBackend;
    use crate::lowrank::{LowRankFactor, Stage1Config};
    use crate::util::timer::StageClock;

    fn factor_and_labels(classes: usize) -> (LowRankFactor, Vec<u32>) {
        let ds = SynthSpec {
            name: "t".into(),
            n: 60 * classes,
            p: 10,
            n_classes: classes,
            sep: 4.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 21,
        }
        .generate();
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(
            &ds.x,
            Kernel::gaussian(0.1),
            &Stage1Config {
                budget: 48,
                ..Default::default()
            },
            &NativeBackend::default(),
            &mut clock,
        )
        .unwrap();
        (factor, ds.labels)
    }

    #[test]
    fn compact_and_view_agree() {
        let (factor, labels) = factor_and_labels(3);
        let subset: Vec<usize> = (0..labels.len()).collect();
        let opts = SolverOptions {
            eps: 1e-4,
            ..Default::default()
        };
        let (h1, _) =
            train_pair(&factor.g, &labels, &subset, 0, 2, &opts, true, None, None).unwrap();
        let (h2, _) =
            train_pair(&factor.g, &labels, &subset, 0, 2, &opts, false, None, None).unwrap();
        assert!(
            (h1.objective - h2.objective).abs() < 1e-3 * (1.0 + h2.objective.abs()),
            "{} vs {}",
            h1.objective,
            h2.objective
        );
    }

    #[test]
    fn all_pairs_trained_in_order() {
        let (factor, labels) = factor_and_labels(4);
        let subset: Vec<usize> = (0..labels.len()).collect();
        let pairs = vec![(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)];
        let opts = SolverOptions::default();
        let (heads, store) =
            train_all_pairs(&factor.g, &labels, &subset, &pairs, &opts, 2, true, None, None)
                .unwrap();
        assert_eq!(heads.len(), 6);
        assert_eq!(store.len(), 6);
        for (h, &(a, b)) in heads.iter().zip(&pairs) {
            assert_eq!(h.pair, (a, b));
            assert!(h.converged, "pair {:?} did not converge", h.pair);
        }
    }

    #[test]
    fn warm_store_accelerates_next_c() {
        let (factor, labels) = factor_and_labels(3);
        let subset: Vec<usize> = (0..labels.len()).collect();
        let pairs = vec![(0u32, 1u32), (0, 2), (1, 2)];
        let opts_small = SolverOptions {
            c: 0.25,
            eps: 1e-4,
            ..Default::default()
        };
        let (_, store) =
            train_all_pairs(&factor.g, &labels, &subset, &pairs, &opts_small, 1, true, None, None)
                .unwrap();
        let opts_big = SolverOptions {
            c: 0.5,
            eps: 1e-4,
            ..Default::default()
        };
        let (cold, _) =
            train_all_pairs(&factor.g, &labels, &subset, &pairs, &opts_big, 1, true, None, None)
                .unwrap();
        let (warm, _) = train_all_pairs(
            &factor.g,
            &labels,
            &subset,
            &pairs,
            &opts_big,
            1,
            true,
            Some(&store),
            None,
        )
        .unwrap();
        let cold_steps: u64 = cold.iter().map(|h| h.steps).sum();
        let warm_steps: u64 = warm.iter().map(|h| h.steps).sum();
        // Warm starts should not cost noticeably more work than cold
        // starts (and typically cost much less across a full C-grid).
        assert!(
            warm_steps <= cold_steps + cold_steps / 5,
            "warm {warm_steps} ≫ cold {cold_steps}"
        );
        for (hw, hc) in warm.iter().zip(&cold) {
            assert!(
                (hw.objective - hc.objective).abs() < 1e-2 * (1.0 + hc.objective.abs()),
                "objectives diverge: {} vs {}",
                hw.objective,
                hc.objective
            );
        }
    }

    #[test]
    fn subset_restricts_training_rows() {
        let (factor, labels) = factor_and_labels(2);
        // Train only on the first half; verify the solver saw <= half rows.
        let subset: Vec<usize> = (0..labels.len() / 2).collect();
        let opts = SolverOptions::default();
        let (head, alpha) =
            train_pair(&factor.g, &labels, &subset, 0, 1, &opts, true, None, None).unwrap();
        assert_eq!(alpha.len(), subset.len());
        assert!(head.sv_count <= subset.len());
    }
}
