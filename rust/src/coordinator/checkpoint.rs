//! Crash-safe training checkpoints: versioned, checksummed, atomically
//! replaced files that let a killed run resume **bit-identically**.
//!
//! Three artifact kinds live in one checkpoint directory, all written
//! through [`crate::util::fsio`] (temp + fsync + rename, CRC-32 footer):
//!
//! * `<tag>.ckpt` — a mid-solve [`SolverSnapshot`]: everything the CD
//!   loop carries across an epoch boundary (α, v, shrinking state, RNG
//!   state, work counters). Written every `--checkpoint-every` epochs;
//!   deleted once the solve completes. Blockwise (out-of-core) solves
//!   store a [`BlockSnapshot`] at the same path under its own kind tag —
//!   it swaps the RNG state for a mid-epoch stripe cursor plus the
//!   carried residual predictions, so a kill between *blocks* of one
//!   epoch resumes bit-identically too.
//! * `<tag>.done.ckpt` — the finished [`Solution`] of one binary solve.
//!   A resumed run returns it verbatim instead of re-solving, so the
//!   pairs that finished before the crash contribute the *same bits* to
//!   the final model as in an uninterrupted run.
//! * `<tag>.cell.ckpt` — a grid cell's journal entry: the fold errors
//!   plus the per-pair warm-start α store. The grid's warm-start chain
//!   along the C axis resumes from exactly the α values the killed run
//!   produced, which is what keeps downstream cells bit-identical.
//!
//! The stage-1 factor `G` is deliberately **not** checkpointed: it is a
//! deterministic function of (data, kernel, stage-1 config, seed) and is
//! cheap relative to stage 2 at the scales where checkpointing matters,
//! so resume recomputes it and only the solver state needs durability.
//!
//! Everything is little-endian binary — no floats or 64-bit counters ride
//! through JSON (the repo's JSON numbers are f64, exact only below 2⁵³,
//! and the RNG state is full-range `u64`).
//!
//! Tags encode the solve's position in the run: `pair_{a}_{b}` for
//! training, `fold{f}_pair_{a}_{b}` for CV, `cell_g{gi}_c{ci}_…` for grid
//! cells. A checkpoint only ever resumes the exact run shape it was taken
//! from; size mismatches fail fast, corrupt files refuse with a clean
//! checksum error instead of resuming wrong.

use crate::coordinator::cv::CvResult;
use crate::coordinator::ovo::WarmStore;
use crate::solver::{
    solve_blockwise_resumable, solve_resumable, BlockProblem, BlockSnapshot, ProblemView,
    Solution, SolverOptions, SolverSnapshot,
};
use crate::util::fsio;
use std::path::{Path, PathBuf};

/// Magic prefix of every checkpoint artifact.
const MAGIC: &[u8; 8] = b"LPDCKPT1";
/// Bumped when the binary layout changes incompatibly.
const VERSION: u32 = 1;

const KIND_SNAPSHOT: u8 = 1;
const KIND_SOLUTION: u8 = 2;
const KIND_CELL: u8 = 3;
const KIND_BLOCK_SNAPSHOT: u8 = 4;

// ---------------------------------------------------------------------
// Little-endian byte (de)serialization.

#[derive(Default)]
struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u64(vs.len() as u64);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u32s(&mut self, vs: &[u32]) {
        self.u64(vs.len() as u64);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u8s(&mut self, vs: &[u8]) {
        self.u64(vs.len() as u64);
        self.buf.extend_from_slice(vs);
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "checkpoint payload truncated at offset {} (want {n} more bytes of {})",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn len(&mut self) -> anyhow::Result<usize> {
        let n = self.u64()?;
        // A corrupt length must not drive a huge allocation; lengths are
        // always bounded by the remaining payload.
        anyhow::ensure!(
            (n as usize) <= self.buf.len(),
            "checkpoint length field {n} exceeds payload size {}",
            self.buf.len()
        );
        Ok(n as usize)
    }
    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn u8s(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.len()?;
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "checkpoint payload has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn header(kind: u8) -> ByteWriter {
    let mut w = ByteWriter::default();
    w.u32(VERSION);
    w.u8(kind);
    w
}

fn open_payload(bytes: &[u8], want_kind: u8, what: &str) -> anyhow::Result<ByteReader<'_>> {
    let mut r = ByteReader::new(bytes);
    let version = r.u32()?;
    anyhow::ensure!(
        version == VERSION,
        "checkpoint version {version} is not the supported version {VERSION}"
    );
    let kind = r.u8()?;
    anyhow::ensure!(
        kind == want_kind,
        "checkpoint kind {kind} where a {what} (kind {want_kind}) was expected"
    );
    Ok(r)
}

// ---------------------------------------------------------------------
// Context

/// Handle on a checkpoint directory plus the snapshot cadence. `Sync`,
/// so the OVO pair farm can checkpoint from pool threads.
#[derive(Clone, Debug)]
pub struct CheckpointCtx {
    dir: PathBuf,
    /// Epochs between mid-solve snapshots (0 = only `done` files).
    pub every: usize,
}

impl CheckpointCtx {
    /// Open (creating if needed) a checkpoint directory.
    pub fn new(dir: &Path, every: usize) -> anyhow::Result<CheckpointCtx> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating checkpoint dir {}: {e}", dir.display()))?;
        Ok(CheckpointCtx { dir: dir.to_path_buf(), every })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn snapshot_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.ckpt"))
    }
    fn done_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.done.ckpt"))
    }
    fn cell_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.cell.ckpt"))
    }

    /// Persist a mid-solve snapshot for `tag` (atomic replace).
    pub fn store_snapshot(&self, tag: &str, s: &SolverSnapshot) -> anyhow::Result<()> {
        let mut w = header(KIND_SNAPSHOT);
        w.u64(s.epochs as u64);
        w.u64(s.steps);
        w.u64(s.active_work);
        w.u64(s.check_work);
        w.u64(s.total_shrunk);
        w.u64(s.total_reactivated);
        for &r in &s.rng {
            w.u64(r);
        }
        w.f32s(&s.alpha);
        w.f32s(&s.v);
        w.u32s(&s.active);
        w.u8s(&s.unchanged);
        w.u32s(&s.inactive);
        fsio::write_checksummed(
            &self.snapshot_path(tag),
            MAGIC,
            &w.buf,
            "ckpt.after_tmp_write",
        )
    }

    /// Load the mid-solve snapshot for `tag`, if one exists. Corruption
    /// is an error, not a silent cold start.
    pub fn load_snapshot(&self, tag: &str) -> anyhow::Result<Option<SolverSnapshot>> {
        let Some(bytes) = fsio::read_checksummed(&self.snapshot_path(tag), MAGIC)? else {
            return Ok(None);
        };
        let mut r = open_payload(&bytes, KIND_SNAPSHOT, "solver snapshot")?;
        let epochs = r.u64()? as usize;
        let steps = r.u64()?;
        let active_work = r.u64()?;
        let check_work = r.u64()?;
        let total_shrunk = r.u64()?;
        let total_reactivated = r.u64()?;
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let alpha = r.f32s()?;
        let v = r.f32s()?;
        let active = r.u32s()?;
        let unchanged = r.u8s()?;
        let inactive = r.u32s()?;
        r.done()?;
        Ok(Some(SolverSnapshot {
            epochs,
            steps,
            alpha,
            v,
            active,
            unchanged,
            inactive,
            total_shrunk,
            total_reactivated,
            rng,
            active_work,
            check_work,
        }))
    }

    /// Persist a mid-solve *blockwise* snapshot for `tag`. Shares the
    /// `<tag>.ckpt` path with classic snapshots (a tag is only ever
    /// solved by one path; a kind mismatch on load fails cleanly), so
    /// [`CheckpointCtx::store_solution`]'s cleanup and
    /// [`CheckpointCtx::gc_prefix`] work unchanged.
    pub fn store_block_snapshot(&self, tag: &str, s: &BlockSnapshot) -> anyhow::Result<()> {
        let mut w = header(KIND_BLOCK_SNAPSHOT);
        w.u64(s.epochs);
        w.u64(s.cursor);
        w.u64(s.steps);
        w.u64(s.active_work);
        w.u64(s.check_work);
        w.u64(s.total_shrunk);
        w.u64(s.total_reactivated);
        w.f64(s.epoch_max_viol);
        w.f32s(&s.alpha);
        w.f32s(&s.v);
        w.f32s(&s.pred);
        w.u32s(&s.active);
        w.u8s(&s.unchanged);
        w.u32s(&s.inactive);
        w.u32s(&s.flagged);
        fsio::write_checksummed(
            &self.snapshot_path(tag),
            MAGIC,
            &w.buf,
            "ckpt.after_tmp_write",
        )
    }

    /// Load the blockwise snapshot for `tag`, if one exists.
    pub fn load_block_snapshot(&self, tag: &str) -> anyhow::Result<Option<BlockSnapshot>> {
        let Some(bytes) = fsio::read_checksummed(&self.snapshot_path(tag), MAGIC)? else {
            return Ok(None);
        };
        let mut r = open_payload(&bytes, KIND_BLOCK_SNAPSHOT, "blockwise snapshot")?;
        let epochs = r.u64()?;
        let cursor = r.u64()?;
        let steps = r.u64()?;
        let active_work = r.u64()?;
        let check_work = r.u64()?;
        let total_shrunk = r.u64()?;
        let total_reactivated = r.u64()?;
        let epoch_max_viol = r.f64()?;
        let alpha = r.f32s()?;
        let v = r.f32s()?;
        let pred = r.f32s()?;
        let active = r.u32s()?;
        let unchanged = r.u8s()?;
        let inactive = r.u32s()?;
        let flagged = r.u32s()?;
        r.done()?;
        Ok(Some(BlockSnapshot {
            epochs,
            cursor,
            steps,
            active_work,
            check_work,
            epoch_max_viol,
            alpha,
            v,
            pred,
            active,
            unchanged,
            inactive,
            flagged,
            total_shrunk,
            total_reactivated,
        }))
    }

    /// Record a completed solve for `tag` and drop its (now redundant)
    /// mid-solve snapshot.
    pub fn store_solution(&self, tag: &str, s: &Solution) -> anyhow::Result<()> {
        let mut w = header(KIND_SOLUTION);
        w.u64(s.steps);
        w.u64(s.epochs as u64);
        w.u64(s.sv_count as u64);
        w.u64(s.final_active as u64);
        w.u8(s.converged as u8);
        w.f64(s.objective);
        w.f64(s.violation);
        w.f64(s.train_secs);
        w.f32s(&s.alpha);
        w.f32s(&s.w);
        fsio::write_checksummed(&self.done_path(tag), MAGIC, &w.buf, "ckpt.after_tmp_write")?;
        let _ = std::fs::remove_file(self.snapshot_path(tag));
        Ok(())
    }

    /// Load a completed solve for `tag`, if recorded.
    pub fn load_solution(&self, tag: &str) -> anyhow::Result<Option<Solution>> {
        let Some(bytes) = fsio::read_checksummed(&self.done_path(tag), MAGIC)? else {
            return Ok(None);
        };
        let mut r = open_payload(&bytes, KIND_SOLUTION, "solution")?;
        let steps = r.u64()?;
        let epochs = r.u64()? as usize;
        let sv_count = r.u64()? as usize;
        let final_active = r.u64()? as usize;
        let converged = r.u8()? != 0;
        let objective = r.f64()?;
        let violation = r.f64()?;
        let train_secs = r.f64()?;
        let alpha = r.f32s()?;
        let w = r.f32s()?;
        r.done()?;
        Ok(Some(Solution {
            alpha,
            w,
            objective,
            steps,
            epochs,
            sv_count,
            converged,
            violation,
            train_secs,
            final_active,
        }))
    }

    /// Run one checkpointed solve: return the recorded solution if `tag`
    /// already completed, otherwise resume from its snapshot (if any) and
    /// run to completion, snapshotting every [`CheckpointCtx::every`]
    /// epochs along the way.
    ///
    /// Snapshot *writes* that fail are logged and skipped — losing a
    /// checkpoint degrades resumability, not the training run. Corrupt
    /// files on the *read* side are hard errors.
    pub fn solve(
        &self,
        tag: &str,
        problem: &ProblemView,
        opts: &SolverOptions,
    ) -> anyhow::Result<Solution> {
        if let Some(sol) = self.load_solution(tag)? {
            crate::log_debug!("ckpt", "{tag}: already complete, skipping solve");
            return Ok(sol);
        }
        let resume = self.load_snapshot(tag)?;
        if let Some(s) = &resume {
            anyhow::ensure!(
                s.alpha.len() == problem.len() && s.v.len() == problem.dim(),
                "checkpoint {tag} is for a {}-variable problem but this run has {} — \
                 the checkpoint dir belongs to a different run configuration",
                s.alpha.len(),
                problem.len()
            );
            crate::log_info!("ckpt", "{tag}: resuming at epoch {}", s.epochs);
        }
        let sol = solve_resumable(problem, opts, resume, self.every, |snap| {
            if let Err(e) = self.store_snapshot(tag, snap) {
                crate::log_warn!("ckpt", "{tag}: snapshot at epoch {} failed: {e:#}", snap.epochs);
            }
        });
        if let Err(e) = self.store_solution(tag, &sol) {
            crate::log_warn!("ckpt", "{tag}: recording completion failed: {e:#}");
        }
        Ok(sol)
    }

    /// Blockwise counterpart of [`CheckpointCtx::solve`]: return the
    /// recorded solution if `tag` already completed, otherwise resume
    /// from its blockwise snapshot — possibly *mid-epoch*, at the stored
    /// stripe cursor — and run to completion.
    pub fn solve_blockwise(
        &self,
        tag: &str,
        problem: &BlockProblem<'_>,
        opts: &SolverOptions,
    ) -> anyhow::Result<Solution> {
        if let Some(sol) = self.load_solution(tag)? {
            crate::log_debug!("ckpt", "{tag}: already complete, skipping solve");
            return Ok(sol);
        }
        let resume = self.load_block_snapshot(tag)?;
        if let Some(s) = &resume {
            anyhow::ensure!(
                s.alpha.len() == problem.len() && s.v.len() == problem.factor.rank,
                "checkpoint {tag} is for a {}-variable problem but this run has {} — \
                 the checkpoint dir belongs to a different run configuration",
                s.alpha.len(),
                problem.len()
            );
            crate::log_info!(
                "ckpt",
                "{tag}: resuming at epoch {} stripe cursor {}",
                s.epochs,
                s.cursor
            );
        }
        let sol = solve_blockwise_resumable(problem, opts, resume, self.every, |snap| {
            if let Err(e) = self.store_block_snapshot(tag, snap) {
                crate::log_warn!("ckpt", "{tag}: snapshot at epoch {} failed: {e:#}", snap.epochs);
            }
        })?;
        if let Err(e) = self.store_solution(tag, &sol) {
            crate::log_warn!("ckpt", "{tag}: recording completion failed: {e:#}");
        }
        Ok(sol)
    }

    /// Journal a completed grid cell: its CV result plus the per-pair
    /// warm-start α store the next C column chains from.
    pub fn store_cell(
        &self,
        tag: &str,
        cv: &CvResult,
        stores: &[WarmStore],
    ) -> anyhow::Result<()> {
        let mut w = header(KIND_CELL);
        w.u64(cv.fold_errors.len() as u64);
        for &e in &cv.fold_errors {
            w.f64(e);
        }
        w.f64(cv.mean_error);
        w.u64(cv.n_binary_problems as u64);
        w.f64(cv.total_secs);
        w.u64(stores.len() as u64);
        for store in stores {
            w.u64(store.len() as u64);
            for entry in store {
                match entry {
                    Some(alpha) => {
                        w.u8(1);
                        w.f32s(alpha);
                    }
                    None => w.u8(0),
                }
            }
        }
        fsio::write_checksummed(&self.cell_path(tag), MAGIC, &w.buf, "ckpt.after_tmp_write")
    }

    /// Load a journaled grid cell, if recorded.
    #[allow(clippy::type_complexity)]
    pub fn load_cell(&self, tag: &str) -> anyhow::Result<Option<(CvResult, Vec<WarmStore>)>> {
        let Some(bytes) = fsio::read_checksummed(&self.cell_path(tag), MAGIC)? else {
            return Ok(None);
        };
        let mut r = open_payload(&bytes, KIND_CELL, "grid cell journal")?;
        let folds = r.len()?;
        let mut fold_errors = Vec::with_capacity(folds);
        for _ in 0..folds {
            fold_errors.push(r.f64()?);
        }
        let mean_error = r.f64()?;
        let n_binary_problems = r.u64()? as usize;
        let total_secs = r.f64()?;
        let n_stores = r.len()?;
        let mut stores = Vec::with_capacity(n_stores);
        for _ in 0..n_stores {
            let n_entries = r.len()?;
            let mut store: WarmStore = Vec::with_capacity(n_entries);
            for _ in 0..n_entries {
                if r.u8()? != 0 {
                    store.push(Some(r.f32s()?));
                } else {
                    store.push(None);
                }
            }
            stores.push(store);
        }
        r.done()?;
        Ok(Some((
            CvResult { fold_errors, mean_error, n_binary_problems, total_secs },
            stores,
        )))
    }

    /// Best-effort removal of every checkpoint artifact whose tag starts
    /// with `prefix` — called when a larger unit (a grid cell) completes
    /// and its per-pair files become redundant.
    pub fn gc_prefix(&self, prefix: &str) {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with(prefix) && name.ends_with(".ckpt") && !name.ends_with(".cell.ckpt")
            {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    fn temp_ctx(name: &str) -> CheckpointCtx {
        let dir = std::env::temp_dir().join(format!("lpdsvm_ckpt_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointCtx::new(&dir, 1).unwrap()
    }

    fn toy_problem(n: usize, seed: u64) -> (Mat, Vec<usize>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut g = Mat::zeros(n, 3);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            g.set(i, 0, cls * 2.0 + rng.normal() as f32 * 0.5);
            g.set(i, 1, rng.normal() as f32 * 0.5);
            g.set(i, 2, rng.normal() as f32 * 0.5);
            y.push(cls);
        }
        (g, (0..n).collect(), y)
    }

    #[test]
    fn snapshot_roundtrip_is_exact() {
        let ctx = temp_ctx("snap");
        let s = SolverSnapshot {
            epochs: 7,
            steps: 12345,
            alpha: vec![0.0, 0.5, 1.0, f32::MIN_POSITIVE],
            v: vec![-1.25, 3.5e-20, 0.0],
            active: vec![3, 0, 2],
            unchanged: vec![0, 4, 5, 1],
            inactive: vec![1],
            total_shrunk: 9,
            total_reactivated: 2,
            rng: [u64::MAX, 1, 0x0123_4567_89AB_CDEF, 42],
            active_work: 999,
            check_work: 111,
        };
        ctx.store_snapshot("t", &s).unwrap();
        let r = ctx.load_snapshot("t").unwrap().unwrap();
        assert_eq!(r.epochs, s.epochs);
        assert_eq!(r.steps, s.steps);
        assert_eq!(r.alpha, s.alpha);
        assert_eq!(r.v, s.v);
        assert_eq!(r.active, s.active);
        assert_eq!(r.unchanged, s.unchanged);
        assert_eq!(r.inactive, s.inactive);
        assert_eq!(r.total_shrunk, s.total_shrunk);
        assert_eq!(r.total_reactivated, s.total_reactivated);
        assert_eq!(r.rng, s.rng);
        assert_eq!(r.active_work, s.active_work);
        assert_eq!(r.check_work, s.check_work);
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn block_snapshot_roundtrip_is_exact() {
        let ctx = temp_ctx("blocksnap");
        let s = BlockSnapshot {
            epochs: 3,
            cursor: 2,
            steps: 777,
            active_work: 700,
            check_work: 77,
            epoch_max_viol: 0.015625,
            alpha: vec![0.0, 1.5, f32::MIN_POSITIVE],
            v: vec![-2.5, 1e-30],
            pred: vec![0.25, -0.75, 0.0],
            active: vec![2, 0],
            unchanged: vec![1, 0, 4],
            inactive: vec![1],
            flagged: vec![0],
            total_shrunk: 5,
            total_reactivated: 1,
        };
        ctx.store_block_snapshot("t", &s).unwrap();
        let r = ctx.load_block_snapshot("t").unwrap().unwrap();
        assert_eq!(r.epochs, s.epochs);
        assert_eq!(r.cursor, s.cursor);
        assert_eq!(r.steps, s.steps);
        assert_eq!(r.active_work, s.active_work);
        assert_eq!(r.check_work, s.check_work);
        assert_eq!(r.epoch_max_viol, s.epoch_max_viol);
        assert_eq!(r.alpha, s.alpha);
        assert_eq!(r.v, s.v);
        assert_eq!(r.pred, s.pred);
        assert_eq!(r.active, s.active);
        assert_eq!(r.unchanged, s.unchanged);
        assert_eq!(r.inactive, s.inactive);
        assert_eq!(r.flagged, s.flagged);
        assert_eq!(r.total_shrunk, s.total_shrunk);
        assert_eq!(r.total_reactivated, s.total_reactivated);
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn block_and_classic_snapshot_kinds_do_not_cross_load() {
        let ctx = temp_ctx("kinds");
        let s = SolverSnapshot {
            epochs: 1,
            steps: 1,
            alpha: vec![0.0],
            v: vec![0.0],
            active: vec![0],
            unchanged: vec![0],
            inactive: vec![],
            total_shrunk: 0,
            total_reactivated: 0,
            rng: [1, 2, 3, 4],
            active_work: 1,
            check_work: 0,
        };
        ctx.store_snapshot("t", &s).unwrap();
        let err = ctx.load_block_snapshot("t").unwrap_err();
        assert!(err.to_string().contains("blockwise snapshot"), "{err:#}");
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn missing_artifacts_are_none() {
        let ctx = temp_ctx("none");
        assert!(ctx.load_snapshot("x").unwrap().is_none());
        assert!(ctx.load_solution("x").unwrap().is_none());
        assert!(ctx.load_cell("x").unwrap().is_none());
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn corrupted_checksum_refuses_resume() {
        let ctx = temp_ctx("corrupt");
        let (g, rows, y) = toy_problem(40, 1);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions::default();
        ctx.solve("pair_0_1", &p, &opts).unwrap();
        // Corrupt the done file in the middle of the alpha payload.
        let path = ctx.dir().join("pair_0_1.done.ckpt");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = ctx.solve("pair_0_1", &p, &opts).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err:#}");
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn completed_solve_is_returned_verbatim() {
        let ctx = temp_ctx("done");
        let (g, rows, y) = toy_problem(60, 2);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions { eps: 1e-4, ..Default::default() };
        let first = ctx.solve("t", &p, &opts).unwrap();
        // Snapshot was cleaned up, done file remains.
        assert!(!ctx.dir().join("t.ckpt").exists());
        assert!(ctx.dir().join("t.done.ckpt").exists());
        let second = ctx.solve("t", &p, &opts).unwrap();
        assert_eq!(first.alpha, second.alpha);
        assert_eq!(first.w, second.w);
        assert_eq!(first.steps, second.steps);
        assert_eq!(first.objective, second.objective);
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn resume_mid_solve_matches_uninterrupted_bits() {
        // Simulate the crash: run once capturing a snapshot, then hand
        // only that snapshot to a fresh context and finish the solve.
        let (g, rows, mut y) = toy_problem(120, 3);
        let mut rng = Rng::new(5);
        for yi in y.iter_mut() {
            if rng.bool(0.2) {
                *yi = -*yi;
            }
        }
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions { c: 2.0, eps: 1e-4, ..Default::default() };
        let uninterrupted = crate::solver::solve(&p, &opts);

        let ctx = temp_ctx("resume");
        // "Crash" after the first snapshot: run the solve but keep only
        // what the checkpoint file holds.
        let mut first_snap = None;
        let _ = solve_resumable(&p, &opts, None, 1, |s| {
            if first_snap.is_none() {
                first_snap = Some(s.clone());
            }
        });
        ctx.store_snapshot("t", &first_snap.expect("at least one epoch")).unwrap();

        let resumed = ctx.solve("t", &p, &opts).unwrap();
        assert_eq!(resumed.alpha, uninterrupted.alpha);
        assert_eq!(resumed.w, uninterrupted.w);
        assert_eq!(resumed.steps, uninterrupted.steps);
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn cell_journal_roundtrip() {
        let ctx = temp_ctx("cell");
        let cv = CvResult {
            fold_errors: vec![0.125, 0.0625],
            mean_error: 0.09375,
            n_binary_problems: 6,
            total_secs: 1.5,
        };
        let stores: Vec<WarmStore> = vec![
            vec![Some(vec![0.5, 0.25]), None, Some(vec![])],
            vec![None],
        ];
        ctx.store_cell("cell_g0_c1", &cv, &stores).unwrap();
        let (rcv, rstores) = ctx.load_cell("cell_g0_c1").unwrap().unwrap();
        assert_eq!(rcv.fold_errors, cv.fold_errors);
        assert_eq!(rcv.mean_error, cv.mean_error);
        assert_eq!(rcv.n_binary_problems, cv.n_binary_problems);
        assert_eq!(rstores, stores);
        let _ = std::fs::remove_dir_all(ctx.dir());
    }

    #[test]
    fn gc_prefix_spares_cell_journals() {
        let ctx = temp_ctx("gc");
        let s = SolverSnapshot {
            epochs: 1,
            steps: 1,
            alpha: vec![0.0],
            v: vec![0.0],
            active: vec![0],
            unchanged: vec![0],
            inactive: vec![],
            total_shrunk: 0,
            total_reactivated: 0,
            rng: [1, 2, 3, 4],
            active_work: 1,
            check_work: 0,
        };
        ctx.store_snapshot("cell_g0_c0_fold0_pair_0_1", &s).unwrap();
        let cv = CvResult {
            fold_errors: vec![0.0],
            mean_error: 0.0,
            n_binary_problems: 1,
            total_secs: 0.0,
        };
        ctx.store_cell("cell_g0_c0", &cv, &[]).unwrap();
        ctx.gc_prefix("cell_g0_c0");
        assert!(ctx.load_snapshot("cell_g0_c0_fold0_pair_0_1").unwrap().is_none());
        assert!(ctx.load_cell("cell_g0_c0").unwrap().is_some());
        let _ = std::fs::remove_dir_all(ctx.dir());
    }
}
