//! The coordination layer — the paper's "parallelism" ingredient.
//!
//! A large SVM job decomposes into many *independent* binary training
//! runs: one per class pair (one-versus-one), per CV fold, per grid point.
//! The paper's key observations, all implemented here:
//!
//! * the expensive stage 1 (landmarks + eigh + `G`) depends only on the
//!   kernel parameter, so it is computed once per γ and shared across all
//!   C values, folds, and class pairs;
//! * warm starts along the C-grid cut epochs substantially;
//! * the resulting pool of independent solves is embarrassingly parallel —
//!   scheduled here over a thread pool (the paper's OpenMP cores / multiple
//!   GPUs).
//!
//! Invariants: results are independent of how solves are scheduled
//! (every job reads shared immutable state and owns its output slot);
//! fold assignment is seed-deterministic and never yields an empty
//! fold; warm starts only ever change iteration counts, not the
//! solution a run converges to; a run killed at any checkpoint boundary
//! and resumed via [`checkpoint`] produces a bit-identical model to an
//! uninterrupted run.

pub mod checkpoint;
pub mod cv;
pub mod grid;
pub mod ovo;
pub mod regression;
pub mod train;
