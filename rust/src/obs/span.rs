//! Thread-attributed timed regions ("spans") recorded into per-thread
//! ring buffers.
//!
//! Design constraints, in priority order:
//!
//! 1. **Zero cost when disabled.** Tracing instruments the solver epoch
//!    loop, the pool worker loop and the serve dispatch path — all hot.
//!    [`Span::new`] performs exactly one `Relaxed` atomic load when
//!    tracing is off and returns a disarmed guard whose `Drop` does
//!    nothing; callers that would allocate a name gate on [`enabled`]
//!    first.
//! 2. **No cross-thread contention when enabled.** Every thread records
//!    into its own buffer; the only global lock is taken once per thread
//!    (registration) and once per export ([`drain`]).
//! 3. **Bounded memory.** Each per-thread buffer is a fixed-capacity
//!    ring: once full, the oldest record is overwritten and counted in
//!    `dropped`, so a long traced run degrades to "most recent window"
//!    instead of unbounded growth.
//!
//! Span hierarchy is implicit: a Chrome-trace viewer (Perfetto) nests
//! complete (`ph: "X"`) events of one thread by timestamp containment,
//! so parent/child links never need to be recorded explicitly.
//!
//! Timestamps are microseconds since the trace epoch — the instant of
//! the first [`enable`] call — which keeps them small, positive, and
//! consistent across threads.

use crate::util::sync::lock_recover;
use std::borrow::Cow;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Records kept per thread before the ring starts overwriting its
/// oldest entries (64Ki spans ≈ a few MB per thread, recent-window
/// semantics beyond that).
pub const RING_CAPACITY: usize = 1 << 16;

/// Is tracing globally enabled? One `Relaxed` load — this is the whole
/// cost instrumented hot paths pay when tracing is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on (idempotent). The first call pins the trace
/// epoch that all span timestamps are measured from.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off. Already-recorded spans stay buffered until
/// [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// The instant all span timestamps are relative to.
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// One completed span, ready for export.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: Cow<'static, str>,
    /// Start, microseconds since the trace epoch.
    pub start_us: u64,
    /// Duration, microseconds.
    pub dur_us: u64,
    /// Structured numeric fields (e.g. the solver's per-epoch KKT
    /// violation and active-set size).
    pub args: Vec<(&'static str, f64)>,
}

/// Per-thread ring buffer plus the identity the exporters need.
struct ThreadBuffer {
    tid: u64,
    name: String,
    ring: Vec<SpanRecord>,
    /// Oldest entry once the ring has wrapped (next overwrite position).
    head: usize,
    /// Records overwritten since the last drain.
    dropped: u64,
}

type SharedBuffer = Arc<Mutex<ThreadBuffer>>;

static REGISTRY: Mutex<Vec<SharedBuffer>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: SharedBuffer = register_thread();
}

fn register_thread() -> SharedBuffer {
    // Relaxed: the counter only mints unique ids; no other data rides it.
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .unwrap_or("unnamed")
        .to_string();
    let buf = Arc::new(Mutex::new(ThreadBuffer {
        tid,
        name,
        ring: Vec::new(),
        head: 0,
        dropped: 0,
    }));
    // lock_recover: telemetry must keep working (and never
    // double-panic) even if a traced thread panicked mid-record.
    lock_recover(&REGISTRY).push(Arc::clone(&buf));
    buf
}

fn record(rec: SpanRecord) {
    LOCAL.with(|buf| {
        // lock_recover: ring-buffer writes keep every field valid at
        // statement boundaries; a poisoned flag carries no information.
        let mut b = lock_recover(buf);
        if b.ring.len() < RING_CAPACITY {
            b.ring.push(rec);
        } else {
            let head = b.head;
            b.ring[head] = rec;
            b.head = (head + 1) % RING_CAPACITY;
            b.dropped += 1;
        }
    });
}

/// Record a span whose timing was measured elsewhere — used for
/// retroactive regions like serve queue-wait, where the interval is only
/// known once the request is pulled into a batch on another thread.
pub fn record_manual(
    name: impl Into<Cow<'static, str>>,
    start: Instant,
    dur: Duration,
    args: Vec<(&'static str, f64)>,
) {
    if !enabled() {
        return;
    }
    record(SpanRecord {
        name: name.into(),
        // `duration_since` saturates to zero for instants before the
        // epoch (e.g. a request enqueued before tracing was enabled).
        start_us: start.duration_since(epoch()).as_micros() as u64,
        dur_us: dur.as_micros() as u64,
        args,
    });
}

/// RAII span guard: times from construction to drop and records the
/// result into the current thread's ring buffer. Construct through
/// [`span`] (or [`Span::new`]); when tracing is disabled the guard is
/// disarmed and costs nothing beyond the one atomic check.
pub struct Span {
    start: Option<Instant>,
    name: Cow<'static, str>,
    args: Vec<(&'static str, f64)>,
}

impl Span {
    #[inline]
    pub fn new(name: impl Into<Cow<'static, str>>) -> Span {
        if enabled() {
            Span {
                start: Some(Instant::now()),
                name: name.into(),
                args: Vec::new(),
            }
        } else {
            Span {
                start: None,
                name: Cow::Borrowed(""),
                args: Vec::new(),
            }
        }
    }

    /// Attach a structured numeric field (no-op on a disarmed span).
    #[inline]
    pub fn arg(&mut self, key: &'static str, value: f64) {
        if self.start.is_some() {
            self.args.push((key, value));
        }
    }

    /// Is this guard actually recording?
    #[inline]
    pub fn armed(&self) -> bool {
        self.start.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            record(SpanRecord {
                name: std::mem::replace(&mut self.name, Cow::Borrowed("")),
                start_us: start.duration_since(epoch()).as_micros() as u64,
                dur_us: start.elapsed().as_micros() as u64,
                args: std::mem::take(&mut self.args),
            });
        }
    }
}

/// Open a span named `name` (see [`Span::new`]).
#[inline]
pub fn span(name: &'static str) -> Span {
    Span::new(name)
}

/// Everything one thread recorded since the last drain.
#[derive(Clone, Debug)]
pub struct ThreadDump {
    pub tid: u64,
    pub thread_name: String,
    /// Records in chronological order.
    pub records: Vec<SpanRecord>,
    /// Records lost to ring overwrites (0 unless the run out-spanned
    /// [`RING_CAPACITY`]).
    pub dropped: u64,
}

/// Snapshot-and-reset every thread's buffer. Buffers of exited threads
/// are included (the registry keeps them alive until drained).
pub fn drain() -> Vec<ThreadDump> {
    // lock_recover on both levels: an export must succeed even after a
    // traced thread panicked while recording (crash forensics is
    // exactly when the buffered spans matter most).
    let registry = lock_recover(&REGISTRY);
    registry
        .iter()
        .map(|buf| {
            let mut b = lock_recover(buf);
            let head = b.head;
            let mut records = std::mem::take(&mut b.ring);
            if head > 0 {
                // The ring wrapped: `head` marks the oldest record.
                records.rotate_left(head);
            }
            b.head = 0;
            let dropped = b.dropped;
            b.dropped = 0;
            ThreadDump {
                tid: b.tid,
                thread_name: b.name.clone(),
                records,
                dropped,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: tracing state is process-global, so everything that needs it
    // enabled lives in ONE test (integration-level coverage is in
    // tests/obs_trace.rs, a separate binary).
    #[test]
    fn spans_record_only_while_enabled() {
        {
            let mut s = Span::new("never-recorded");
            s.arg("x", 1.0);
            assert!(!s.armed());
        }
        enable();
        {
            let mut s = Span::new("recorded");
            s.arg("k", 2.5);
            assert!(s.armed());
        }
        record_manual(
            "manual",
            epoch(),
            Duration::from_micros(7),
            vec![("n", 3.0)],
        );
        disable();
        {
            let s = Span::new("after-disable");
            assert!(!s.armed());
        }
        let dumps = drain();
        let mine: Vec<&SpanRecord> = dumps.iter().flat_map(|d| d.records.iter()).collect();
        let names: Vec<&str> = mine.iter().map(|r| r.name.as_ref()).collect();
        assert!(names.contains(&"recorded"), "{names:?}");
        assert!(names.contains(&"manual"), "{names:?}");
        assert!(!names.contains(&"never-recorded"), "{names:?}");
        assert!(!names.contains(&"after-disable"), "{names:?}");
        let rec = mine.iter().find(|r| r.name == "recorded").unwrap();
        assert_eq!(rec.args, vec![("k", 2.5)]);
        let man = mine.iter().find(|r| r.name == "manual").unwrap();
        assert_eq!(man.dur_us, 7);
        assert_eq!(man.args, vec![("n", 3.0)]);
        // Drained means gone.
        let again = drain();
        assert!(again.iter().all(|d| d.records.is_empty()));
        assert!(dumps.iter().all(|d| d.dropped == 0));
    }
}
