//! Exporters for the recorded telemetry: Chrome-trace-event JSON (loads
//! in Perfetto / `chrome://tracing`), Prometheus text exposition
//! (version 0.0.4), and `report::Table` summaries for the CLI.
//!
//! The Chrome format uses complete (`ph: "X"`) events — one per
//! [`SpanRecord`] — plus one `thread_name` metadata event per thread, so
//! the viewer reconstructs the span hierarchy from per-thread timestamp
//! containment. Everything is built on [`crate::util::json`]; no
//! external dependency.

use crate::obs::metrics::Histogram;
use crate::obs::span::{SpanRecord, ThreadDump};
use crate::report::Table;
use crate::util::json::{self, Json};
use crate::util::threads::PoolStats;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// Build the Chrome trace-event document for a set of thread dumps.
/// Timestamps and durations ride in microseconds, as the format expects.
pub fn chrome_trace(dumps: &[ThreadDump]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for d in dumps {
        if d.records.is_empty() {
            continue;
        }
        events.push(json::obj(vec![
            ("name", json::s("thread_name")),
            ("ph", json::s("M")),
            ("pid", json::unum(1)),
            ("tid", json::unum(d.tid)),
            ("args", json::obj(vec![("name", json::s(&d.thread_name))])),
        ]));
        for r in &d.records {
            events.push(json::obj(vec![
                ("name", json::s(&r.name)),
                ("cat", json::s("lpdsvm")),
                ("ph", json::s("X")),
                ("pid", json::unum(1)),
                ("tid", json::unum(d.tid)),
                ("ts", json::unum(r.start_us)),
                ("dur", json::unum(r.dur_us)),
                (
                    "args",
                    json::obj_owned(
                        r.args
                            .iter()
                            .map(|(k, v)| (k.to_string(), json::num(*v))),
                    ),
                ),
            ]));
        }
    }
    json::obj(vec![
        ("traceEvents", json::arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Drop the Chrome trace to disk (the `--trace out.json` target).
pub fn write_chrome_trace(path: &Path, dumps: &[ThreadDump]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace(dumps).to_string() + "\n")?;
    Ok(())
}

/// Aggregate the recorded spans by name into a per-phase summary table
/// (count / total / mean), heaviest phases first.
pub fn phase_table(dumps: &[ThreadDump]) -> Table {
    let mut agg: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
    for d in dumps {
        for r in &d.records {
            let e = agg.entry(r.name.as_ref()).or_insert((0, 0));
            e.0 += 1;
            e.1 += r.dur_us;
        }
    }
    let mut rows: Vec<(&str, u64, u64)> =
        agg.into_iter().map(|(n, (c, t))| (n, c, t)).collect();
    rows.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(b.0)));
    let mut t = Table::new("trace phase summary", &["span", "count", "total s", "mean ms"]);
    for (name, count, total_us) in rows {
        t.row(&[
            name.to_string(),
            count.to_string(),
            Table::secs(total_us as f64 / 1e6),
            format!("{:.3}", total_us as f64 / 1e3 / count.max(1) as f64),
        ]);
    }
    t
}

/// Render the pool's per-worker busy/idle/queue-wait accounting.
pub fn utilization_table(stats: &PoolStats) -> Table {
    let mut t = Table::new(
        "pool utilization",
        &["worker", "tasks", "busy s", "idle s", "busy %", "wait ms"],
    );
    for (i, w) in stats.workers.iter().enumerate() {
        let busy = w.busy.as_secs_f64();
        let idle = w.idle.as_secs_f64();
        let util = 100.0 * busy / (busy + idle).max(1e-12);
        let wait_ms = w.queue_wait.as_secs_f64() * 1e3 / w.tasks.max(1) as f64;
        t.row(&[
            format!("lpdsvm-pool-{i}"),
            w.tasks.to_string(),
            Table::secs(busy),
            Table::secs(idle),
            format!("{util:.1}"),
            format!("{wait_ms:.3}"),
        ]);
    }
    t
}

/// Incremental builder for the Prometheus text exposition format
/// (0.0.4): declare each metric family once, then append its samples.
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Write the `# HELP` / `# TYPE` header for one metric family.
    /// `kind` is `counter`, `gauge`, or `histogram`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    /// Append one sample line, with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                let _ = write!(self.out, "{k}=\"{}\"", escape_label(v));
            }
            self.out.push('}');
        }
        // Counters are exact integers below 2⁵³; print them without a
        // fraction so `grep`-style checks see the natural form.
        if value.fract() == 0.0 && value.abs() < 9.007_199_254_740_992e15 {
            let _ = writeln!(self.out, " {}", value as i64);
        } else {
            let _ = writeln!(self.out, " {value}");
        }
    }

    /// Append the `_bucket`/`_sum`/`_count` series for one histogram.
    /// The family (type `histogram`) must already be declared. `le`
    /// edges are the histogram's exact inclusive integer bounds
    /// ([`Histogram::bucket_upper`]); empty buckets above the highest
    /// occupied one collapse into `+Inf`.
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let counts = h.bucket_counts();
        let last = counts.iter().rposition(|&c| c > 0).unwrap_or(0);
        let bucket_name = format!("{name}_bucket");
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate().take(last + 1) {
            cum += c;
            let le = Histogram::bucket_upper(i);
            if le == u64::MAX {
                // The clamped top bucket has no finite edge; it is
                // covered by the +Inf sample below.
                continue;
            }
            let le_s = le.to_string();
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", &le_s));
            self.sample(&bucket_name, &ls, cum as f64);
        }
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket_name, &ls, h.count() as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum() as f64);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    /// The accumulated exposition text.
    pub fn render(self) -> String {
        self.out
    }
}

/// Escape a label value per the exposition format (backslash, quote,
/// newline).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::borrow::Cow;

    fn dump(records: Vec<SpanRecord>) -> ThreadDump {
        ThreadDump {
            tid: 7,
            thread_name: "test-thread".into(),
            records,
            dropped: 0,
        }
    }

    fn rec(name: &'static str, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord {
            name: Cow::Borrowed(name),
            start_us,
            dur_us,
            args: vec![("n", 3.0)],
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let doc = chrome_trace(&[dump(vec![rec("train", 0, 100), rec("epoch", 10, 20)])]);
        let back = Json::parse(&doc.to_string()).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 metadata + 2 X events.
        assert_eq!(events.len(), 3);
        let meta = &events[0];
        assert_eq!(meta.get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(
            meta.get("args").unwrap().get("name").unwrap().as_str(),
            Some("test-thread")
        );
        let x = &events[1];
        assert_eq!(x.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(x.get("name").unwrap().as_str(), Some("train"));
        assert_eq!(x.get("ts").unwrap().as_u64(), Some(0));
        assert_eq!(x.get("dur").unwrap().as_u64(), Some(100));
        assert_eq!(x.get("args").unwrap().get("n").unwrap().as_f64(), Some(3.0));
        // Threads with no records emit nothing.
        let empty = chrome_trace(&[dump(vec![])]);
        assert_eq!(
            empty.get("traceEvents").unwrap().as_arr().unwrap().len(),
            0
        );
    }

    #[test]
    fn phase_table_aggregates() {
        let t = phase_table(&[dump(vec![
            rec("epoch", 0, 10),
            rec("epoch", 10, 30),
            rec("prep", 0, 100),
        ])]);
        let r = t.render();
        assert!(r.contains("epoch"));
        assert!(r.contains("prep"));
        // Heaviest first: prep (100µs total) before epoch (40µs).
        assert!(r.find("prep").unwrap() < r.find("epoch").unwrap(), "{r}");
    }

    #[test]
    fn prometheus_samples_and_histogram() {
        let mut p = PromText::new();
        p.family("demo_total", "counter", "A demo counter.");
        p.sample("demo_total", &[], 42.0);
        p.sample("demo_total", &[("model", "a\"b")], 1.0);
        let h = Histogram::new();
        h.record(0);
        h.record(3);
        h.record(700);
        p.family("demo_us", "histogram", "A demo histogram.");
        p.histogram("demo_us", &[("model", "m")], &h);
        let text = p.render();
        assert!(text.contains("# TYPE demo_total counter"), "{text}");
        assert!(text.contains("demo_total 42\n"), "{text}");
        assert!(text.contains("demo_total{model=\"a\\\"b\"} 1\n"), "{text}");
        // Cumulative buckets: le=0 → 1, le=3 → 2, le=1023 → 3, +Inf → 3.
        assert!(text.contains("demo_us_bucket{model=\"m\",le=\"0\"} 1\n"), "{text}");
        assert!(text.contains("demo_us_bucket{model=\"m\",le=\"3\"} 2\n"), "{text}");
        assert!(
            text.contains("demo_us_bucket{model=\"m\",le=\"1023\"} 3\n"),
            "{text}"
        );
        assert!(
            text.contains("demo_us_bucket{model=\"m\",le=\"+Inf\"} 3\n"),
            "{text}"
        );
        assert!(text.contains("demo_us_sum{model=\"m\"} 703\n"), "{text}");
        assert!(text.contains("demo_us_count{model=\"m\"} 3\n"), "{text}");
    }
}
