//! Observability: tracing spans, leveled logging, shared telemetry
//! primitives, and exporters — dependency-free, like everything else in
//! the crate.
//!
//! The paper's recipe is "polishing, parallelism, and more RAM"; this
//! module is how the repo *sees* each ingredient instead of asserting
//! it: solver polishing progress (per-epoch KKT violation, active-set
//! shrinkage) rides as span fields, parallelism shows up as per-worker
//! pool utilization and thread-attributed spans, and the serve path
//! splits latency into queue-wait vs service time.
//!
//! Components:
//! - [`span`] — hierarchical, thread-attributed timed regions in
//!   per-thread ring buffers. Disabled cost: one relaxed atomic load.
//! - [`log`] — leveled `key=value` stderr logging (`--log-level`),
//!   via the crate-root `log_error!` … `log_trace!` macros.
//! - [`metrics`] — the shared log₂ [`Histogram`] (promoted from
//!   `serve::metrics`; serve re-exports it).
//! - [`export`] — Chrome-trace-event JSON for Perfetto (`--trace`),
//!   Prometheus text exposition (`GET /metrics?format=prometheus`), and
//!   `report::Table` phase/utilization summaries.
//!
//! Contract: with tracing disabled and the default `info` log level,
//! instrumented hot paths (solver epochs, pool slots, serve dispatch)
//! pay one atomic check and nothing else — no allocation, no lock, no
//! formatting.

pub mod export;
pub mod log;
pub mod metrics;
pub mod span;

pub use metrics::Histogram;
pub use span::{enabled, span, Span};
