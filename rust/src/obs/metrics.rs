//! Shared telemetry primitives — currently the log₂-bucketed
//! [`Histogram`] that both the serve metrics and the solver's epoch
//! timing report quantiles through (promoted here from `serve::metrics`
//! so train and serve summarise distributions identically; `serve`
//! re-exports it, so existing paths keep working).

// lint: allow-file(atomic-ordering-justified) — histogram buckets are
// monotone counters recorded with relaxed atomics by design (see the
// `Histogram` docs); snapshots tolerate approximation, and no data is
// published through them.

use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets (bucket 0 counts zeros, the top
/// bucket clamps everything ≥ 2³⁸).
pub const BUCKETS: usize = 40;

/// Histogram over `u64` values with power-of-two buckets: bucket `i`
/// (i ≥ 1) counts values in `[2^(i-1), 2^i)`; bucket 0 counts zeros.
/// Percentiles are reported as the upper edge of the covering bucket —
/// at most 2× off, which is plenty for latency reporting.
///
/// Recording is plain relaxed atomics, so any number of threads can
/// record without a lock; snapshots are approximate under concurrent
/// writers, which is fine for operational telemetry.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

// [T; 40] has no Default impl (arrays stop at 32), hence the manual one.
impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values (for mean reconstruction and the
    /// Prometheus `_sum` sample).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-bucket counts (index `i` as in
    /// [`Histogram::bucket_upper`]).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// Inclusive upper bound of bucket `i`: 0 for the zero bucket,
    /// `2^i − 1` in between, `u64::MAX` for the clamped top bucket.
    /// Values are integers, so these bounds are exact (Prometheus `le`
    /// edges).
    pub fn bucket_upper(i: usize) -> u64 {
        match i {
            0 => 0,
            i if i >= BUCKETS - 1 => u64::MAX,
            i => (1u64 << i) - 1,
        }
    }

    /// Upper bucket edge covering quantile `q` ∈ [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return match i {
                    0 => 0,
                    // The top bucket is clamped — it holds every value ≥
                    // 2^(BUCKETS-2), so its nominal power-of-two edge can
                    // under-report by orders of magnitude. The tracked max
                    // is a true upper bound for anything landing here (the
                    // overall max always lives in the highest occupied
                    // bucket).
                    i if i == BUCKETS - 1 => self.max(),
                    i => 1u64 << i,
                };
            }
        }
        self.max()
    }

    /// Machine-readable summary (count / mean / tail quantiles / max).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("count", json::unum(self.count())),
            ("mean", json::num(self.mean())),
            ("p50", json::unum(self.quantile(0.50))),
            ("p90", json::unum(self.quantile(0.90))),
            ("p99", json::unum(self.quantile(0.99))),
            ("max", json::unum(self.max())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_stats() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.sum(), 1107);
        assert!((h.mean() - (1107.0 / 7.0)).abs() < 1e-9);
        // q=0 clamps to the first recorded value's bucket (zero here).
        assert_eq!(h.quantile(0.0), 0);
        // All seven values are ≤ 1024, so p100 lands in that bucket.
        assert_eq!(h.quantile(1.0), 1024);
        // Median of {0,1,1,2,3,100,1000} is 2 → bucket [2,4) → edge 4.
        assert_eq!(h.quantile(0.5), 4);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_huge_values_clamp() {
        // Regression: values ≥ 2^39 clamp into the top bucket, whose
        // nominal edge (1 << 39) used to be reported even when the
        // recorded max was far larger. The top bucket must report the
        // tracked max instead.
        let h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), u64::MAX);
        assert_eq!(h.quantile(1.0), u64::MAX);
        assert_eq!(h.max(), u64::MAX);
        // Any quantile landing in the clamped bucket reports the max (an
        // upper bound, consistent with the bucket-edge semantics).
        h.record(1u64 << 45);
        assert_eq!(h.quantile(0.01), u64::MAX);
        // Values below the top bucket keep their power-of-two upper edge.
        let h2 = Histogram::new();
        h2.record(1000);
        assert_eq!(h2.quantile(0.5), 1024);
    }

    #[test]
    fn bucket_edges_cover_the_counts() {
        // The cumulative bucket view must agree with `count()` and the
        // inclusive upper bounds must actually bound their bucket.
        let h = Histogram::new();
        for v in [0u64, 1, 5, 700, 1 << 20] {
            h.record(v);
        }
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), BUCKETS);
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(Histogram::bucket_upper(0), 0);
        assert_eq!(Histogram::bucket_upper(1), 1);
        assert_eq!(Histogram::bucket_upper(10), 1023);
        assert_eq!(Histogram::bucket_upper(BUCKETS - 1), u64::MAX);
        // 700 ∈ [512, 1024) → bucket 10, inclusive upper bound 1023.
        assert_eq!(counts[10], 1);
    }
}
