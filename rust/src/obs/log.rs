//! Leveled structured logging to stderr.
//!
//! One line per event, `key=value` style so the output greps and parses
//! without a log pipeline:
//!
//! ```text
//! ts=1.042913 level=info target=serve engine up workers=4 max_batch=256
//! ```
//!
//! `ts` is seconds since the first log line (process-relative, like the
//! span clock). The level is a process-global `AtomicU8` — one `Relaxed`
//! load per *suppressed* event, checked inside the macros before any
//! formatting happens, so `log_debug!` in a hot loop costs nothing at
//! the default `info` level.
//!
//! Diagnostics go through these macros ([`crate::log_error!`] …
//! [`crate::log_trace!`]); *results* (report tables, JSON emission, CLI
//! summaries) intentionally stay on stdout so they can be piped without
//! the diagnostics interleaving.

use std::fmt::Arguments;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first. Ordering follows verbosity:
/// `Error < Warn < Info < Debug < Trace`, and an event is emitted when
/// its level is ≤ the configured one.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the process-wide log level.
pub fn set_level(level: Level) {
    // Relaxed: the level is one independent byte; a racing reader
    // seeing the old value logs one more (or fewer) line, nothing else.
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Set the level from a CLI string, erroring on unknown names.
pub fn set_level_str(s: &str) -> anyhow::Result<()> {
    let level = Level::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown log level '{s}' (error|warn|info|debug|trace)"))?;
    set_level(level);
    Ok(())
}

/// The currently configured level.
pub fn level() -> Level {
    // Relaxed: see `set_level` — no data rides on the level byte.
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Would an event at `level` be emitted right now?
#[inline]
pub fn enabled(level: Level) -> bool {
    // Relaxed: see `set_level` — no data rides on the level byte.
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one line. Called by the `log_*!` macros after their level check;
/// prefer the macros so suppressed events never format.
pub fn write(level: Level, target: &str, args: Arguments<'_>) {
    let ts = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("ts={ts:.6} level={} target={target} {args}", level.as_str());
}

/// Log at `error` level: `log_error!("target", "msg {}", v)`.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write($crate::obs::log::Level::Error, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `warn` level: `log_warn!("target", "msg {}", v)`.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write($crate::obs::log::Level::Warn, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `info` level: `log_info!("target", "msg {}", v)`.
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write($crate::obs::log::Level::Info, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `debug` level: `log_debug!("target", "msg {}", v)`.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write($crate::obs::log::Level::Debug, $target, format_args!($($arg)*));
        }
    };
}

/// Log at `trace` level: `log_trace!("target", "msg {}", v)`.
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Trace) {
            $crate::obs::log::write($crate::obs::log::Level::Trace, $target, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::Debug.as_str(), "debug");
    }

    #[test]
    fn set_level_str_rejects_unknown() {
        assert!(set_level_str("nope").is_err());
    }

    // `enabled`/`set_level` mutate process-global state shared with
    // concurrently running tests, so the behavioural check lives in
    // tests/obs_trace.rs where it owns the process.
}
