//! LIBSVM/SVMlight text format I/O.
//!
//! Format per line: `<label> <index>:<value> <index>:<value> ...` with
//! 1-based feature indices. This is the format of every dataset in the
//! paper's table 1 (all published on the LIBSVM site), so real files can be
//! dropped in place of the synthetic analogues without code changes.

use crate::data::dataset::Dataset;
use crate::data::sparse::SparseMatrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse a LIBSVM file. Labels may be arbitrary integers or ±1; they are
/// remapped to contiguous class ids `0..n_classes` in sorted label order
/// (so −1 → 0, +1 → 1 for the usual binary convention).
pub fn read(path: &Path) -> Result<Dataset> {
    crate::util::fault::point("data.load")?;
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening LIBSVM file {}", path.display()))?;
    parse(BufReader::new(file), &path.display().to_string())
}

/// Parse LIBSVM-format text from any reader.
pub fn parse<R: BufRead>(reader: R, name: &str) -> Result<Dataset> {
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut max_col = 0u32;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_txt = parts.next().unwrap();
        let label_f: f64 = label_txt
            .parse::<f64>()
            .with_context(|| format!("line {}: bad label '{label_txt}'", lineno + 1))?;
        // Labels must be integral class ids (`1.0`/`-1.0` spellings are
        // fine). A plain `as i64` truncation here silently collapsed
        // fractional labels (0.5 and 0.7 both became class 0), mapped
        // NaN/Inf to arbitrary ids, and saturated anything ≥ 2⁶³ — all
        // of which merge distinct labels into one class.
        if !label_f.is_finite()
            || label_f.fract() != 0.0
            || label_f.abs() >= i64::MAX as f64
        {
            bail!(
                "line {}: non-integral label '{label_txt}' (labels must be \
                 i64-range integer class ids or ±1; fractional, non-finite \
                 or oversized values would be silently collapsed)",
                lineno + 1
            );
        }
        let label = label_f as i64;
        let mut entries = Vec::new();
        for tok in parts {
            let (idx_txt, val_txt) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad feature '{tok}'", lineno + 1))?;
            let idx: u32 = idx_txt
                .parse()
                .with_context(|| format!("line {}: bad index '{idx_txt}'", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based, found 0", lineno + 1);
            }
            let val: f32 = val_txt
                .parse()
                .with_context(|| format!("line {}: bad value '{val_txt}'", lineno + 1))?;
            let col = idx - 1;
            max_col = max_col.max(col + 1);
            entries.push((col, val));
        }
        entries.sort_by_key(|&(c, _)| c);
        // Duplicate indices: keep the last occurrence (LIBSVM behaviour).
        entries.dedup_by(|a, b| {
            if a.0 == b.0 {
                b.1 = a.1;
                true
            } else {
                false
            }
        });
        raw_labels.push(label);
        rows.push(entries);
    }

    // Remap labels to 0..k in sorted order.
    let mut label_map: BTreeMap<i64, u32> = BTreeMap::new();
    for &l in &raw_labels {
        let next = label_map.len() as u32;
        label_map.entry(l).or_insert(next);
    }
    // Re-sort the map values so classes are ordered by raw label.
    let sorted: Vec<i64> = label_map.keys().copied().collect();
    for (i, l) in sorted.iter().enumerate() {
        label_map.insert(*l, i as u32);
    }
    let labels: Vec<u32> = raw_labels.iter().map(|l| label_map[l]).collect();
    let n_classes = label_map.len().max(1);

    let x = SparseMatrix::from_rows(max_col as usize, &rows);
    Ok(Dataset::new(name, x, labels, n_classes))
}

/// Write a dataset in LIBSVM format. Binary datasets are written with
/// labels −1/+1; multiclass with raw class ids.
pub fn write(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.len() {
        let label = if ds.n_classes == 2 {
            if ds.labels[i] == 1 { 1 } else { -1 }
        } else {
            ds.labels[i] as i64
        };
        write!(f, "{label}")?;
        let (cols, vals) = ds.x.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            write!(f, " {}:{}", c + 1, v)?;
        }
        writeln!(f)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic_binary() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1.0\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.n_classes, 2);
        // −1 sorts before +1 → class 0.
        assert_eq!(ds.labels, vec![1, 0, 1]);
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(0).1, &[0.5, 1.5]);
    }

    #[test]
    fn parse_multiclass_remaps_sorted() {
        let text = "3 1:1\n7 1:1\n3 2:1\n0 1:1\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n_classes, 3);
        assert_eq!(ds.labels, vec![1, 2, 1, 0]); // 0→0, 3→1, 7→2
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "# header\n+1 1:1.0 # trailing\n\n-1 2:1.0\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.len(), 2);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse(Cursor::new("+1 0:1.0\n"), "t").is_err());
    }

    #[test]
    fn rejects_malformed_feature() {
        assert!(parse(Cursor::new("+1 1=3\n"), "t").is_err());
        assert!(parse(Cursor::new("x 1:1\n"), "t").is_err());
    }

    #[test]
    fn rejects_fractional_labels_with_line_number() {
        // 0.5 and 0.7 used to truncate into the same class id 0.
        let err = parse(Cursor::new("0.5 1:1.0\n0.7 1:2.0\n"), "t").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 1"), "{msg}");
        assert!(msg.contains("non-integral label '0.5'"), "{msg}");
        // The line number points at the offender, not at line 1 blindly.
        let err2 = parse(Cursor::new("1 1:1.0\n0.7 1:2.0\n"), "t").unwrap_err();
        assert!(format!("{err2:#}").contains("line 2"), "{err2:#}");
    }

    #[test]
    fn rejects_non_finite_labels() {
        for bad in ["nan", "NaN", "inf", "-inf"] {
            let text = format!("{bad} 1:1.0\n");
            let err = parse(Cursor::new(text), "t").unwrap_err();
            assert!(
                format!("{err:#}").contains("non-integral label"),
                "{bad}: {err:#}"
            );
        }
    }

    #[test]
    fn rejects_labels_beyond_i64_range() {
        // 1e19 and 9.3e18 would both saturate to i64::MAX and merge.
        for bad in ["1e19", "9.3e18", "-1e300"] {
            let text = format!("{bad} 1:1.0\n");
            let err = parse(Cursor::new(text), "t").unwrap_err();
            assert!(
                format!("{err:#}").contains("non-integral label"),
                "{bad}: {err:#}"
            );
        }
        // The largest exactly-representable i64-range whole floats pass.
        let ds = parse(Cursor::new("9e18 1:1.0\n-9e18 1:1.0\n"), "t").unwrap();
        assert_eq!(ds.n_classes, 2);
    }

    #[test]
    fn accepts_float_spelled_integral_labels() {
        // `1.0` / `-1.0` are the common tool output for ±1 and must keep
        // parsing (as must exponent forms of whole numbers).
        let ds = parse(Cursor::new("1.0 1:0.5\n-1.0 2:1.5\n1e1 1:1.0\n"), "t").unwrap();
        assert_eq!(ds.n_classes, 3); // −1, 1, 10 → three classes
        assert_eq!(ds.labels, vec![1, 0, 2]);
    }

    #[test]
    fn roundtrip_through_file() {
        let text = "+1 1:0.25 4:-1\n-1 2:3\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        let dir = std::env::temp_dir().join("lpdsvm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        write(&ds, &path).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.x.to_dense(), ds.x.to_dense());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unsorted_indices_get_sorted() {
        let ds = parse(Cursor::new("+1 3:3 1:1\n-1 1:1\n"), "t").unwrap();
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(0).1, &[1.0, 3.0]);
    }
}
