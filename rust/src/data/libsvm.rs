//! LIBSVM/SVMlight text format I/O.
//!
//! Format per line: `<label> <index>:<value> <index>:<value> ...` with
//! 1-based feature indices. This is the format of every dataset in the
//! paper's table 1 (all published on the LIBSVM site), so real files can be
//! dropped in place of the synthetic analogues without code changes.
//!
//! The line-level parsing helpers are shared with the out-of-core reader
//! in [`crate::data::block`]: the sharded source re-parses the same bytes
//! with the same code, which is what makes the streaming path produce the
//! same matrix — entry for entry — as a monolithic [`read`].

use crate::data::dataset::Dataset;
use crate::data::sparse::SparseMatrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse a LIBSVM file. Labels may be arbitrary integers or ±1; they are
/// remapped to contiguous class ids `0..n_classes` in sorted label order
/// (so −1 → 0, +1 → 1 for the usual binary convention).
pub fn read(path: &Path) -> Result<Dataset> {
    crate::util::fault::point("data.load")?;
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening LIBSVM file {}", path.display()))?;
    parse(BufReader::new(file), &path.display().to_string())
}

/// Strip the `#` comment and surrounding whitespace from one raw line,
/// then split off and validate the label. Returns `None` for blank or
/// comment-only lines. `lineno` is 1-based and only used for errors.
///
/// The remainder (feature tokens, possibly empty) is returned unparsed so
/// callers can choose the full parse ([`parse_entries`]) or the cheap
/// index-only scan ([`scan_max_index`]).
pub(crate) fn parse_label(raw: &str, lineno: usize) -> Result<Option<(i64, &str)>> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let (label_txt, rest) = match line.split_once(|c: char| c.is_ascii_whitespace()) {
        Some((l, r)) => (l, r),
        None => (line, ""),
    };
    let label_f: f64 = label_txt
        .parse::<f64>()
        .with_context(|| format!("line {lineno}: bad label '{label_txt}'"))?;
    // Labels must be integral class ids (`1.0`/`-1.0` spellings are
    // fine). A plain `as i64` truncation here silently collapsed
    // fractional labels (0.5 and 0.7 both became class 0), mapped
    // NaN/Inf to arbitrary ids, and saturated anything ≥ 2⁶³ — all
    // of which merge distinct labels into one class.
    if !label_f.is_finite() || label_f.fract() != 0.0 || label_f.abs() >= i64::MAX as f64 {
        bail!(
            "line {lineno}: non-integral label '{label_txt}' (labels must be \
             i64-range integer class ids or ±1; fractional, non-finite \
             or oversized values would be silently collapsed)"
        );
    }
    Ok(Some((label_f as i64, rest)))
}

/// Fully parse the feature tokens of one line into sorted, de-duplicated
/// `(col, value)` entries (0-based columns, duplicate indices keep the
/// last occurrence — LIBSVM behaviour). Also returns the line's column
/// bound, i.e. `max(col) + 1` (0 for an empty feature list).
pub(crate) fn parse_entries(rest: &str, lineno: usize) -> Result<(Vec<(u32, f32)>, u32)> {
    let mut entries = Vec::new();
    let mut max_col = 0u32;
    for tok in rest.split_ascii_whitespace() {
        let (idx_txt, val_txt) = tok
            .split_once(':')
            .with_context(|| format!("line {lineno}: bad feature '{tok}'"))?;
        let idx: u32 = idx_txt
            .parse()
            .with_context(|| format!("line {lineno}: bad index '{idx_txt}'"))?;
        if idx == 0 {
            bail!("line {lineno}: LIBSVM indices are 1-based, found 0");
        }
        let val: f32 = val_txt
            .parse()
            .with_context(|| format!("line {lineno}: bad value '{val_txt}'"))?;
        let col = idx - 1;
        max_col = max_col.max(col + 1);
        entries.push((col, val));
    }
    entries.sort_by_key(|&(c, _)| c);
    // Duplicate indices: keep the last occurrence (LIBSVM behaviour).
    entries.dedup_by(|a, b| {
        if a.0 == b.0 {
            b.1 = a.1;
            true
        } else {
            false
        }
    });
    Ok((entries, max_col))
}

/// Cheap first-pass scan of one line's feature tokens: validate and parse
/// the indices only (values are never touched — float parsing is the
/// expensive part), returning the line's column bound `max(col) + 1`.
/// Used by the sharded reader's label pass to learn `n_cols` without
/// materializing any features.
pub(crate) fn scan_max_index(rest: &str, lineno: usize) -> Result<u32> {
    let mut max_col = 0u32;
    for tok in rest.split_ascii_whitespace() {
        let (idx_txt, _) = tok
            .split_once(':')
            .with_context(|| format!("line {lineno}: bad feature '{tok}'"))?;
        let idx: u32 = idx_txt
            .parse()
            .with_context(|| format!("line {lineno}: bad index '{idx_txt}'"))?;
        if idx == 0 {
            bail!("line {lineno}: LIBSVM indices are 1-based, found 0");
        }
        max_col = max_col.max(idx); // idx is 1-based, so idx == col + 1
    }
    Ok(max_col)
}

/// Map raw integer labels to contiguous class ids `0..k` ordered by raw
/// label value — the exact remap [`parse`] applies, factored out so the
/// sharded reader assigns identical class ids from its label-only pass.
pub(crate) fn build_label_map(raw: &[i64]) -> BTreeMap<i64, u32> {
    let mut label_map: BTreeMap<i64, u32> = BTreeMap::new();
    for &l in raw {
        let next = label_map.len() as u32;
        label_map.entry(l).or_insert(next);
    }
    // Re-sort the map values so classes are ordered by raw label.
    let sorted: Vec<i64> = label_map.keys().copied().collect();
    for (i, l) in sorted.iter().enumerate() {
        label_map.insert(*l, i as u32);
    }
    label_map
}

/// Parse LIBSVM-format text from any reader.
///
/// The read loop reuses one line buffer (`read_line` into a cleared
/// `String`) instead of `reader.lines()`'s fresh allocation per line —
/// this is the hot loop of the out-of-core streaming path, which re-parses
/// every shard once per epoch.
pub fn parse<R: BufRead>(mut reader: R, name: &str) -> Result<Dataset> {
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut max_col = 0u32;

    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        let Some((label, rest)) = parse_label(&line, lineno)? else {
            continue;
        };
        let (entries, line_cols) = parse_entries(rest, lineno)?;
        max_col = max_col.max(line_cols);
        raw_labels.push(label);
        rows.push(entries);
    }

    // Remap labels to 0..k in sorted order.
    let label_map = build_label_map(&raw_labels);
    let labels: Vec<u32> = raw_labels.iter().map(|l| label_map[l]).collect();
    let n_classes = label_map.len().max(1);

    let x = SparseMatrix::from_rows(max_col as usize, &rows);
    Ok(Dataset::new(name, x, labels, n_classes))
}

/// Write a dataset in LIBSVM format. Binary datasets are written with
/// labels −1/+1; multiclass with raw class ids.
pub fn write(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.len() {
        let label = if ds.n_classes == 2 {
            if ds.labels[i] == 1 { 1 } else { -1 }
        } else {
            ds.labels[i] as i64
        };
        write!(f, "{label}")?;
        let (cols, vals) = ds.x.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            write!(f, " {}:{}", c + 1, v)?;
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Outcome of [`split_shards`]: the shard layout plus the input's label
/// histogram (keyed by *raw* label, before class-id remapping).
#[derive(Debug)]
pub struct SplitSummary {
    /// Total data rows across all shards.
    pub rows: usize,
    /// Data rows per shard, in shard order.
    pub shard_rows: Vec<usize>,
    /// Raw label → row count over the whole input.
    pub label_counts: BTreeMap<i64, usize>,
}

/// Shard a LIBSVM file into `parts` block files `part-00000.svm`,
/// `part-00001.svm`, … under `out_dir`, plus a `MANIFEST.tsv` of
/// per-shard row counts.
///
/// Rows are copied **verbatim** (original bytes, original order) into
/// contiguous runs of ⌈n/parts⌉ data rows — concatenating the shards
/// reproduces the input byte for byte, so a model trained from the shard
/// directory is byte-identical to one trained from the monolithic file.
/// Blank and comment lines ride along with whichever shard is current.
/// Labels are validated (and counted) along the way, so a malformed file
/// fails here rather than at training time.
pub fn split_shards(input: &Path, out_dir: &Path, parts: usize) -> Result<SplitSummary> {
    anyhow::ensure!(parts >= 1, "--parts must be >= 1");

    // Pass 1: count data rows and build the label histogram.
    let file = std::fs::File::open(input)
        .with_context(|| format!("opening LIBSVM file {}", input.display()))?;
    let mut reader = BufReader::new(file);
    let mut label_counts: BTreeMap<i64, usize> = BTreeMap::new();
    let mut rows = 0usize;
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        if let Some((label, _)) = parse_label(&line, lineno)? {
            *label_counts.entry(label).or_insert(0) += 1;
            rows += 1;
        }
    }
    anyhow::ensure!(rows > 0, "{} contains no data rows", input.display());

    // Pass 2: verbatim copy into contiguous shards of ceil(n/parts) rows.
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating shard dir {}", out_dir.display()))?;
    let per_shard = rows.div_ceil(parts);
    let open_shard = |i: usize| -> Result<std::io::BufWriter<std::fs::File>> {
        let path = out_dir.join(format!("part-{i:05}.svm"));
        Ok(std::io::BufWriter::new(std::fs::File::create(&path).with_context(
            || format!("creating shard {}", path.display()),
        )?))
    };
    let file = std::fs::File::open(input)
        .with_context(|| format!("opening LIBSVM file {}", input.display()))?;
    let mut reader = BufReader::new(file);
    let mut shard_rows = vec![0usize; parts];
    let mut shard = 0usize;
    let mut out = open_shard(0)?;
    let mut lineno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        let is_data = parse_label(&line, lineno)?.is_some();
        if is_data && shard_rows[shard] == per_shard && shard + 1 < parts {
            out.flush()?;
            shard += 1;
            out = open_shard(shard)?;
        }
        out.write_all(line.as_bytes())?;
        if is_data {
            shard_rows[shard] += 1;
        }
    }
    out.flush()?;
    // Trailing empty shards still get created: the directory always holds
    // exactly `parts` shard files, as asked.
    for i in (shard + 1)..parts {
        open_shard(i)?.flush()?;
    }

    let mut manifest = String::from("shard\trows\n");
    for (i, &r) in shard_rows.iter().enumerate() {
        manifest.push_str(&format!("part-{i:05}.svm\t{r}\n"));
    }
    std::fs::write(out_dir.join("MANIFEST.tsv"), manifest)?;

    Ok(SplitSummary { rows, shard_rows, label_counts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic_binary() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1.0\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.n_classes, 2);
        // −1 sorts before +1 → class 0.
        assert_eq!(ds.labels, vec![1, 0, 1]);
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(0).1, &[0.5, 1.5]);
    }

    #[test]
    fn parse_multiclass_remaps_sorted() {
        let text = "3 1:1\n7 1:1\n3 2:1\n0 1:1\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n_classes, 3);
        assert_eq!(ds.labels, vec![1, 2, 1, 0]); // 0→0, 3→1, 7→2
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "# header\n+1 1:1.0 # trailing\n\n-1 2:1.0\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        assert_eq!(ds.len(), 2);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse(Cursor::new("+1 0:1.0\n"), "t").is_err());
    }

    #[test]
    fn rejects_malformed_feature() {
        assert!(parse(Cursor::new("+1 1=3\n"), "t").is_err());
        assert!(parse(Cursor::new("x 1:1\n"), "t").is_err());
    }

    #[test]
    fn rejects_fractional_labels_with_line_number() {
        // 0.5 and 0.7 used to truncate into the same class id 0.
        let err = parse(Cursor::new("0.5 1:1.0\n0.7 1:2.0\n"), "t").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 1"), "{msg}");
        assert!(msg.contains("non-integral label '0.5'"), "{msg}");
        // The line number points at the offender, not at line 1 blindly.
        let err2 = parse(Cursor::new("1 1:1.0\n0.7 1:2.0\n"), "t").unwrap_err();
        assert!(format!("{err2:#}").contains("line 2"), "{err2:#}");
    }

    #[test]
    fn rejects_non_finite_labels() {
        for bad in ["nan", "NaN", "inf", "-inf"] {
            let text = format!("{bad} 1:1.0\n");
            let err = parse(Cursor::new(text), "t").unwrap_err();
            assert!(
                format!("{err:#}").contains("non-integral label"),
                "{bad}: {err:#}"
            );
        }
    }

    #[test]
    fn rejects_labels_beyond_i64_range() {
        // 1e19 and 9.3e18 would both saturate to i64::MAX and merge.
        for bad in ["1e19", "9.3e18", "-1e300"] {
            let text = format!("{bad} 1:1.0\n");
            let err = parse(Cursor::new(text), "t").unwrap_err();
            assert!(
                format!("{err:#}").contains("non-integral label"),
                "{bad}: {err:#}"
            );
        }
        // The largest exactly-representable i64-range whole floats pass.
        let ds = parse(Cursor::new("9e18 1:1.0\n-9e18 1:1.0\n"), "t").unwrap();
        assert_eq!(ds.n_classes, 2);
    }

    #[test]
    fn accepts_float_spelled_integral_labels() {
        // `1.0` / `-1.0` are the common tool output for ±1 and must keep
        // parsing (as must exponent forms of whole numbers).
        let ds = parse(Cursor::new("1.0 1:0.5\n-1.0 2:1.5\n1e1 1:1.0\n"), "t").unwrap();
        assert_eq!(ds.n_classes, 3); // −1, 1, 10 → three classes
        assert_eq!(ds.labels, vec![1, 0, 2]);
    }

    #[test]
    fn roundtrip_through_file() {
        let text = "+1 1:0.25 4:-1\n-1 2:3\n";
        let ds = parse(Cursor::new(text), "t").unwrap();
        let dir = std::env::temp_dir().join("lpdsvm_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        write(&ds, &path).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.x.to_dense(), ds.x.to_dense());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unsorted_indices_get_sorted() {
        let ds = parse(Cursor::new("+1 3:3 1:1\n-1 1:1\n"), "t").unwrap();
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(0).1, &[1.0, 3.0]);
    }

    #[test]
    fn split_shards_concatenation_is_byte_identical() {
        let text = "# header comment\n+1 1:0.5 3:1.5\n-1 2:2.0\n\n+1 1:1.0\n-1 3:0.25\n+1 2:0.125";
        let dir = std::env::temp_dir().join(format!("lpdsvm_split_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("all.svm");
        std::fs::write(&input, text).unwrap();
        let out = dir.join("shards");
        let s = split_shards(&input, &out, 3).unwrap();
        assert_eq!(s.rows, 5);
        assert_eq!(s.shard_rows, vec![2, 2, 1]);
        assert_eq!(s.label_counts[&1], 3);
        assert_eq!(s.label_counts[&-1], 2);
        let mut joined = Vec::new();
        for i in 0..3 {
            joined.extend(std::fs::read(out.join(format!("part-{i:05}.svm"))).unwrap());
        }
        assert_eq!(joined, text.as_bytes());
        let manifest = std::fs::read_to_string(out.join("MANIFEST.tsv")).unwrap();
        assert!(manifest.contains("part-00001.svm\t2"), "{manifest}");
        // More parts than rows: trailing shards exist and are empty.
        let out2 = dir.join("wide");
        let s2 = split_shards(&input, &out2, 8).unwrap();
        assert_eq!(s2.shard_rows.iter().sum::<usize>(), 5);
        assert!(out2.join("part-00007.svm").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_max_index_matches_full_parse() {
        let rest = "3:0.5 9:1.25 2:-1";
        let (entries, max_col) = parse_entries(rest, 1).unwrap();
        assert_eq!(scan_max_index(rest, 1).unwrap(), max_col);
        assert_eq!(entries.iter().map(|e| e.0).max().unwrap() + 1, max_col);
        assert_eq!(scan_max_index("", 1).unwrap(), 0);
        assert!(scan_max_index("0:1.0", 1).is_err());
    }
}
