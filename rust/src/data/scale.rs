//! Feature scaling.
//!
//! The paper notes EigenPro's sensitivity to data scaling; LIBSVM practice
//! is to scale features to `[0,1]` or `[-1,1]` before training. We provide
//! per-feature min-max scaling (fit on train, apply to test) and unit-norm
//! row scaling.

use crate::data::dataset::Dataset;
use crate::data::sparse::SparseMatrix;

/// Per-feature affine scaling parameters `x' = (x - min) * scale`.
#[derive(Clone, Debug)]
pub struct MinMaxScaler {
    pub min: Vec<f32>,
    pub scale: Vec<f32>,
}

impl MinMaxScaler {
    /// Fit to map each feature's observed range onto `[0, 1]`.
    ///
    /// NOTE on sparsity: for sparse data we treat the implicit zeros as
    /// observations (LIBSVM's `svm-scale` does the same), so a feature with
    /// range [0, hi] keeps zeros at zero and the output stays sparse.
    pub fn fit(x: &SparseMatrix) -> Self {
        let mut min = vec![0.0f32; x.cols];
        let mut max = vec![0.0f32; x.cols];
        for i in 0..x.rows {
            let (c, v) = x.row(i);
            for (&ci, &vi) in c.iter().zip(v) {
                let j = ci as usize;
                if vi < min[j] {
                    min[j] = vi;
                }
                if vi > max[j] {
                    max[j] = vi;
                }
            }
        }
        let scale = min
            .iter()
            .zip(&max)
            .map(|(&lo, &hi)| if hi > lo { 1.0 / (hi - lo) } else { 0.0 })
            .collect();
        MinMaxScaler { min, scale }
    }

    /// Apply the scaling. Entries are shifted only where `min != 0`, which
    /// for LIBSVM-style data keeps the matrix sparse.
    ///
    /// CAVEAT (shared with LIBSVM's `svm-scale`): for features with
    /// negative values the implicit zeros *conceptually* map to a positive
    /// target `(0−min)·scale`, which a sparse transform cannot
    /// materialise; stored entries are scaled exactly, implicit zeros stay
    /// zero. Prefer non-negative encodings when exact affine semantics
    /// matter.
    pub fn transform(&self, x: &SparseMatrix) -> SparseMatrix {
        let mut out = SparseMatrix::empty(x.cols);
        let mut buf = Vec::new();
        for i in 0..x.rows {
            buf.clear();
            let (c, v) = x.row(i);
            for (&ci, &vi) in c.iter().zip(v) {
                let j = ci as usize;
                let scaled = (vi - self.min[j]) * self.scale[j];
                buf.push((ci, scaled));
            }
            out.push_row(&buf);
        }
        out
    }

    pub fn transform_dataset(&self, ds: &Dataset) -> Dataset {
        Dataset::new(&ds.name, self.transform(&ds.x), ds.labels.clone(), ds.n_classes)
    }
}

/// Scale every row to unit L2 norm (zero rows untouched).
pub fn unit_norm_rows(x: &SparseMatrix) -> SparseMatrix {
    let mut out = SparseMatrix::empty(x.cols);
    let mut buf = Vec::new();
    for i in 0..x.rows {
        buf.clear();
        let (c, v) = x.row(i);
        let norm = v.iter().map(|a| a * a).sum::<f32>().sqrt();
        let inv = if norm > 0.0 { 1.0 / norm } else { 0.0 };
        for (&ci, &vi) in c.iter().zip(v) {
            buf.push((ci, vi * inv));
        }
        out.push_row(&buf);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_maps_to_unit_interval() {
        let x = SparseMatrix::from_rows(
            2,
            &[vec![(0, 2.0), (1, -4.0)], vec![(0, 6.0), (1, 4.0)]],
        );
        let s = MinMaxScaler::fit(&x);
        let t = s.transform(&x);
        let d = t.to_dense();
        for &v in &d.data {
            assert!((0.0..=1.0).contains(&v), "{v}");
        }
        // Feature 0: range [0 (implicit), 6] -> 2.0 maps to 1/3.
        assert!((d.at(0, 0) - 2.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn constant_feature_maps_to_zero() {
        let x = SparseMatrix::from_rows(1, &[vec![(0, 5.0)], vec![(0, 5.0)]]);
        let s = MinMaxScaler::fit(&x);
        let t = s.transform(&x);
        // range [0, 5] -> 5 maps to 1. A truly constant nonzero feature
        // still has implicit-zero min, so it scales, not collapses.
        assert!((t.to_dense().at(0, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn unit_norm() {
        let x = SparseMatrix::from_rows(3, &[vec![(0, 3.0), (2, 4.0)], vec![]]);
        let u = unit_norm_rows(&x);
        assert!((u.row_sq_norm(0) - 1.0).abs() < 1e-6);
        assert_eq!(u.row(1).0.len(), 0);
    }

    #[test]
    fn fit_on_train_apply_to_test() {
        let train = SparseMatrix::from_rows(1, &[vec![(0, 0.0)], vec![(0, 10.0)]]);
        let test = SparseMatrix::from_rows(1, &[vec![(0, 20.0)]]);
        let s = MinMaxScaler::fit(&train);
        let t = s.transform(&test);
        // Out-of-range test values extrapolate (no clamping), like svm-scale.
        assert!((t.to_dense().at(0, 0) - 2.0).abs() < 1e-6);
    }
}
