//! Synthetic analogues of the paper's benchmark datasets (table 1).
//!
//! The real files (Adult/a9a, Epsilon, SUSY, MNIST-8M, ImageNet-VGG16
//! features) are not available in this offline environment, so each is
//! replaced by a generator matched on the *shape* that drives the paper's
//! measurements: number of points `n` (scaled by a user factor), input
//! dimension `p`, number of classes, sparsity pattern, and a separation
//! parameter tuned so the relative accuracy ordering of the solvers
//! (exact > low-rank > LLSVM) reproduces. See DESIGN.md §Substitutions.
//!
//! The generative model is a Gaussian-mixture classifier task: class
//! centres drawn on a sphere of radius `sep` inside a `latent`-dimensional
//! discriminative subspace, points = centre + unit noise on the latent
//! dims. The remaining `p − latent` dims carry pure distractor noise whose
//! *total* energy is `noise²` (per-coordinate std `noise/√(p−latent)`), so
//! task difficulty is independent of the ambient dimension — only the
//! latent geometry and `sep` control the Bayes error. Features are
//! optionally passed through a ReLU-with-threshold to create the sparse
//! non-negative structure of VGG features (ImageNet) or binarised to mimic
//! one-hot categorical encodings (Adult).

use crate::data::dataset::Dataset;
use crate::data::sparse::SparseMatrix;
use crate::util::rng::Rng;

/// Post-processing applied to the raw Gaussian features.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FeatureStyle {
    /// Keep dense real values (Epsilon, SUSY, MNIST-style).
    Dense,
    /// `max(0, x - threshold)` — sparse non-negative, like ReLU activations.
    Relu { threshold: f32 },
    /// `x > threshold ? 1 : 0` — sparse binary, like one-hot categoricals.
    Binary { threshold: f32 },
}

/// Specification of a synthetic classification task.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub n_classes: usize,
    /// Distance of class centres from the origin; controls Bayes error.
    pub sep: f32,
    /// Latent dimension of the class-discriminative subspace (<= p). Noise
    /// fills the remaining dimensions, making the task genuinely
    /// kernel-nonlinear for small `latent`.
    pub latent: usize,
    /// Total distractor-noise energy spread across the `p − latent`
    /// non-discriminative dimensions.
    pub noise: f32,
    pub style: FeatureStyle,
    pub seed: u64,
}

impl SynthSpec {
    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        assert!(self.latent >= 1 && self.latent <= self.p);
        let mut rng = Rng::new(self.seed);
        // Class centres in the latent subspace, on a sphere of radius sep.
        let mut centres = vec![vec![0.0f32; self.latent]; self.n_classes];
        for c in centres.iter_mut() {
            let mut norm = 0.0f32;
            for v in c.iter_mut() {
                *v = rng.normal() as f32;
                norm += *v * *v;
            }
            let inv = self.sep / norm.sqrt().max(1e-12);
            for v in c.iter_mut() {
                *v *= inv;
            }
        }
        // Second moon-like nonlinearity: flip the centre sign for half of
        // each class's points and add a fixed per-class offset in one extra
        // latent direction, so classes are NOT linearly separable and the
        // RBF kernel genuinely helps (exact solvers should beat low-rank).
        // Distractor dims: constant total energy regardless of p.
        let n_noise = self.p.saturating_sub(self.latent + 1);
        let noise_std = if n_noise > 0 {
            self.noise / (n_noise as f32).sqrt()
        } else {
            0.0
        };
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.n);
        let mut labels = Vec::with_capacity(self.n);
        let mut buf = vec![0.0f32; self.p];
        for i in 0..self.n {
            let cls = (i % self.n_classes) as u32;
            let centre = &centres[cls as usize];
            let flip = if rng.bool(0.5) { -1.0f32 } else { 1.0 };
            for (j, b) in buf.iter_mut().enumerate() {
                if j < self.latent {
                    *b = flip * centre[j] + rng.normal() as f32;
                } else {
                    *b = noise_std * rng.normal() as f32;
                }
            }
            // Bimodal marker dimension: lets the RBF kernel undo the flip
            // (the task is a 2-cluster-per-class mixture, deliberately not
            // linearly separable in the latent space).
            if self.latent < self.p {
                buf[self.latent] = flip * self.sep * 0.7 + rng.normal() as f32;
            }
            let entries = match self.style {
                FeatureStyle::Dense => buf
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| (j as u32, v))
                    .collect::<Vec<_>>(),
                FeatureStyle::Relu { threshold } => buf
                    .iter()
                    .enumerate()
                    .filter_map(|(j, &v)| {
                        let r = v - threshold;
                        (r > 0.0).then_some((j as u32, r))
                    })
                    .collect(),
                FeatureStyle::Binary { threshold } => buf
                    .iter()
                    .enumerate()
                    .filter_map(|(j, &v)| (v > threshold).then_some((j as u32, 1.0)))
                    .collect(),
            };
            rows.push(entries);
            labels.push(cls);
        }
        let x = SparseMatrix::from_rows(self.p, &rows);
        Dataset::new(&self.name, x, labels, self.n_classes)
    }
}

/// The five benchmark datasets of the paper's table 1, as synthetic
/// analogues. `scale ∈ (0, 1]` shrinks `n` (and for ImageNet the class
/// count) to fit the available compute; `scale = 1` reproduces the paper's
/// row counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    Adult,
    Epsilon,
    Susy,
    Mnist8m,
    ImageNet,
}

/// Hyperparameters the paper reports per dataset (table 1), mapped to the
/// synthetic analogue's geometry: budget `B`, regularisation `C`, and a
/// Gaussian-kernel bandwidth appropriate for the generated feature scale.
#[derive(Clone, Debug)]
pub struct PaperSpec {
    pub dataset: PaperDataset,
    pub synth: SynthSpec,
    pub budget: usize,
    pub c: f64,
    pub gamma: f64,
}

impl PaperDataset {
    pub fn all() -> [PaperDataset; 5] {
        [
            PaperDataset::Adult,
            PaperDataset::Epsilon,
            PaperDataset::Susy,
            PaperDataset::Mnist8m,
            PaperDataset::ImageNet,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Adult => "adult",
            PaperDataset::Epsilon => "epsilon",
            PaperDataset::Susy => "susy",
            PaperDataset::Mnist8m => "mnist8m",
            PaperDataset::ImageNet => "imagenet",
        }
    }

    pub fn from_name(name: &str) -> Option<PaperDataset> {
        PaperDataset::all()
            .into_iter()
            .find(|d| d.name() == name)
    }

    /// The paper's row count for this dataset (table 1).
    pub fn paper_n(&self) -> usize {
        match self {
            PaperDataset::Adult => 32_561,
            PaperDataset::Epsilon => 400_000,
            PaperDataset::Susy => 5_000_000,
            PaperDataset::Mnist8m => 8_100_000,
            PaperDataset::ImageNet => 1_281_167,
        }
    }

    /// Raise `scale` so the generated dataset has at least `min_n` points.
    /// Benches use this so the smaller datasets are not scaled into noise
    /// while the giant ones stay tractable.
    pub fn scale_with_floor(&self, scale: f64, min_n: usize) -> f64 {
        scale.max(min_n as f64 / self.paper_n() as f64).min(1.0)
    }

    /// Build the scaled spec. Budgets scale with sqrt(scale) (clamped) so
    /// the B≪n regime of the paper is preserved at small scales, with two
    /// guard rails active only at reduced scale: B never exceeds n/4 (the
    /// low-rank regime must stay low-rank) and never falls below
    /// 2·classes (OVO pairs need a usable subspace).
    pub fn spec(&self, scale: f64, seed: u64) -> PaperSpec {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
        let sn = |n: usize| ((n as f64 * scale) as usize).max(64);
        let sb = |b: usize| ((b as f64 * scale.sqrt()) as usize).clamp(16, 4096);
        let mut spec = self.spec_inner(scale, seed, &sn, &sb);
        let n = spec.synth.n;
        let floor = (2 * spec.synth.n_classes).min(n / 4).max(16);
        spec.budget = spec.budget.max(floor).min((n / 4).max(16));
        spec
    }

    fn spec_inner(
        &self,
        scale: f64,
        seed: u64,
        sn: &dyn Fn(usize) -> usize,
        sb: &dyn Fn(usize) -> usize,
    ) -> PaperSpec {
        match self {
            // Adult a9a: 32,561 × 123 binary one-hot features, 2 classes.
            PaperDataset::Adult => PaperSpec {
                dataset: *self,
                synth: SynthSpec {
                    name: "adult".into(),
                    n: sn(32_561),
                    p: 123,
                    n_classes: 2,
                    sep: 2.6,
                    latent: 6,
                    noise: 1.0,
                    style: FeatureStyle::Binary { threshold: 0.8 },
                    seed,
                },
                budget: sb(1_000),
                c: 32.0,       // 2^5
                gamma: 0.06,   // ≈ 1/(2(latent+1+noise²)) for the binarised geometry
            },
            // Epsilon: 400,000 × 2,000 dense, 2 classes, hard.
            PaperDataset::Epsilon => PaperSpec {
                dataset: *self,
                synth: SynthSpec {
                    name: "epsilon".into(),
                    n: sn(400_000),
                    p: 2_000,
                    n_classes: 2,
                    sep: 2.2,
                    latent: 24,
                    noise: 1.0,
                    style: FeatureStyle::Dense,
                    seed: seed ^ 1,
                },
                budget: sb(10_000),
                c: 32.0,
                gamma: 0.02,   // ≈ 1/(2·(latent+1+noise²)), latent 24
            },
            // SUSY: 5,000,000 × 18 dense physics features, 2 classes,
            // ~20% irreducible error.
            PaperDataset::Susy => PaperSpec {
                dataset: *self,
                synth: SynthSpec {
                    name: "susy".into(),
                    n: sn(5_000_000),
                    p: 18,
                    n_classes: 2,
                    sep: 1.3,
                    latent: 6,
                    noise: 1.0,
                    style: FeatureStyle::Dense,
                    seed: seed ^ 2,
                },
                budget: sb(1_000),
                c: 32.0,
                gamma: 0.06,
            },
            // MNIST-8M: 8,100,000 × 784, 10 classes.
            PaperDataset::Mnist8m => PaperSpec {
                dataset: *self,
                synth: SynthSpec {
                    name: "mnist8m".into(),
                    n: sn(8_100_000),
                    p: 784,
                    n_classes: 10,
                    sep: 6.0,
                    latent: 16,
                    noise: 1.0,
                    style: FeatureStyle::Relu { threshold: 0.5 },
                    seed: seed ^ 3,
                },
                budget: sb(10_000),
                c: 32.0,
                gamma: 0.028,  // ≈ 1/(2·(latent+1+noise²)), latent 16
            },
            // ImageNet: 1,281,167 × 25,088 sparse ReLU features, 1000
            // classes. Class count scales with sqrt(scale) too — the OVO
            // pair count (the paper's headline "half a million classifiers")
            // scales quadratically, so this keeps the bench tractable while
            // exercising the same scheduler.
            PaperDataset::ImageNet => {
                let classes = ((1000.0 * scale.sqrt()) as usize).clamp(8, 1000);
                PaperSpec {
                    dataset: *self,
                    synth: SynthSpec {
                        name: "imagenet".into(),
                        n: sn(1_281_167),
                        p: ((25_088.0 * scale.sqrt()) as usize).clamp(256, 25_088),
                        n_classes: classes,
                        sep: 5.0,
                        latent: 32,
                        noise: 1.0,
                        style: FeatureStyle::Relu { threshold: 1.0 },
                        seed: seed ^ 4,
                    },
                    budget: sb(1_000),
                    c: 16.0, // 2^4
                    gamma: 0.015, // ≈ 1/(2·(latent+1+noise²)), latent 32
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let spec = SynthSpec {
            name: "t".into(),
            n: 200,
            p: 20,
            n_classes: 3,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 1,
        };
        let ds = spec.generate();
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 20);
        assert_eq!(ds.n_classes, 3);
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c >= 66));
    }

    #[test]
    fn deterministic() {
        let spec = PaperDataset::Adult.spec(0.01, 7);
        let a = spec.synth.generate();
        let b = spec.synth.generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.x.to_dense(), b.x.to_dense());
    }

    #[test]
    fn binary_style_is_binary_and_sparse() {
        let spec = PaperDataset::Adult.spec(0.005, 3);
        let ds = spec.synth.generate();
        assert!(ds.x.values.iter().all(|&v| v == 1.0));
        assert!(ds.x.density() < 0.5, "density {}", ds.x.density());
    }

    #[test]
    fn relu_style_nonnegative_sparse() {
        let spec = PaperDataset::ImageNet.spec(0.001, 3);
        let ds = spec.synth.generate();
        assert!(ds.x.values.iter().all(|&v| v > 0.0));
        assert!(ds.x.density() < 0.5, "density {}", ds.x.density());
    }

    #[test]
    fn dense_style_full_rows() {
        let spec = PaperDataset::Susy.spec(0.0001, 3);
        let ds = spec.synth.generate();
        assert_eq!(ds.dim(), 18);
        // Dense rows store every coordinate (normals are never exactly 0).
        assert_eq!(ds.x.nnz(), ds.len() * 18);
    }

    #[test]
    fn scaling_shrinks_n_and_budget() {
        let s1 = PaperDataset::Epsilon.spec(1.0, 1);
        let s2 = PaperDataset::Epsilon.spec(0.01, 1);
        assert_eq!(s1.synth.n, 400_000);
        assert_eq!(s2.synth.n, 4_000);
        assert!(s2.budget < s1.budget);
        assert!(s2.budget >= 16);
    }

    #[test]
    fn imagenet_classes_scale() {
        let s = PaperDataset::ImageNet.spec(0.01, 1);
        assert_eq!(s.synth.n_classes, 100);
        let s_full = PaperDataset::ImageNet.spec(1.0, 1);
        assert_eq!(s_full.synth.n_classes, 1000);
    }

    #[test]
    fn names_roundtrip() {
        for d in PaperDataset::all() {
            assert_eq!(PaperDataset::from_name(d.name()), Some(d));
        }
        assert_eq!(PaperDataset::from_name("nope"), None);
    }

    #[test]
    fn classes_are_separable_with_enough_sep() {
        // Sanity: 1-NN on a high-sep dataset should do well, confirming
        // the generator produces learnable structure.
        let spec = SynthSpec {
            name: "sep".into(),
            n: 300,
            p: 10,
            n_classes: 2,
            sep: 6.0,
            latent: 3,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 5,
        };
        let ds = spec.generate();
        let dense = ds.x.to_dense();
        let mut errors = 0;
        for i in 0..100 {
            // nearest other point
            let mut best = (f32::MAX, 0usize);
            for j in 0..ds.len() {
                if j == i {
                    continue;
                }
                let d2: f32 = dense
                    .row(i)
                    .iter()
                    .zip(dense.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d2 < best.0 {
                    best = (d2, j);
                }
            }
            if ds.labels[best.1] != ds.labels[i] {
                errors += 1;
            }
        }
        assert!(errors < 15, "1-NN errors {errors}/100 — generator broken?");
    }
}
