//! CSR sparse matrix.
//!
//! The paper emphasises (§4 "Multi-core and GPU Implementation") that
//! neither ThunderSVM nor EigenPro supports sparse data properly, and
//! implements all batch kernel operations on top of sparse matrix products.
//! This CSR type is our equivalent: it backs both the exact-kernel baseline
//! and stage 1 of LPD-SVM, with row dot products, row norms, and
//! sparse-dense block products (the `K(X_chunk, L)` building block).

use crate::linalg::Mat;

/// Compressed sparse row matrix, f32 values, usize column indices.
#[derive(Clone, Debug, Default)]
pub struct SparseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,  // len rows+1
    pub indices: Vec<u32>,   // len nnz, column ids
    pub values: Vec<f32>,    // len nnz
}

impl SparseMatrix {
    pub fn empty(cols: usize) -> Self {
        SparseMatrix {
            rows: 0,
            cols,
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Build from per-row (column, value) lists. Columns within a row must
    /// be strictly increasing (asserted in debug builds).
    pub fn from_rows(cols: usize, rows: &[Vec<(u32, f32)>]) -> Self {
        let mut m = SparseMatrix::empty(cols);
        for r in rows {
            m.push_row(r);
        }
        m
    }

    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        debug_assert!(
            entries.windows(2).all(|w| w[0].0 < w[1].0),
            "row entries must be sorted by column"
        );
        for &(c, v) in entries {
            assert!((c as usize) < self.cols, "column {c} out of bounds");
            self.indices.push(c);
            self.values.push(v);
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (column indices, values) of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Squared L2 norm of row `i`.
    pub fn row_sq_norm(&self, i: usize) -> f32 {
        let (_, v) = self.row(i);
        v.iter().map(|x| x * x).sum()
    }

    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row_sq_norm(i)).collect()
    }

    /// Dot product of two sparse rows (merge join on sorted indices).
    pub fn row_dot(&self, i: usize, other: &SparseMatrix, j: usize) -> f32 {
        let (ci, vi) = self.row(i);
        let (cj, vj) = other.row(j);
        sparse_dot(ci, vi, cj, vj)
    }

    /// Row `i` as owned `(column, value)` pairs — the wire format of one
    /// serving request (`serve::ServeEngine::submit`).
    pub fn row_entries(&self, i: usize) -> Vec<(u32, f32)> {
        let (c, v) = self.row(i);
        c.iter().copied().zip(v.iter().copied()).collect()
    }

    /// Dense copy of row `i` (length `cols`).
    pub fn row_dense(&self, i: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.cols];
        let (c, v) = self.row(i);
        for (&ci, &vi) in c.iter().zip(v) {
            out[ci as usize] = vi;
        }
        out
    }

    /// Convert to a dense matrix.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (c, v) = self.row(i);
            let row = m.row_mut(i);
            for (&ci, &vi) in c.iter().zip(v) {
                row[ci as usize] = vi;
            }
        }
        m
    }

    /// Build from a dense matrix, dropping explicit zeros.
    pub fn from_dense(m: &Mat) -> Self {
        let mut out = SparseMatrix::empty(m.cols);
        let mut buf = Vec::new();
        for i in 0..m.rows {
            buf.clear();
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    buf.push((j as u32, v));
                }
            }
            out.push_row(&buf);
        }
        out
    }

    /// `self[rows_sel] @ denseᵀ` where `dense` is row-major `k×cols`:
    /// the sparse-dense product at the heart of batch kernel evaluation
    /// (inner products of data chunk vs landmark matrix). Output is
    /// `rows_sel.len() × k`.
    pub fn select_matmul_dense_t(&self, rows_sel: &[usize], dense: &Mat) -> Mat {
        assert_eq!(dense.cols, self.cols, "dimension mismatch");
        let k = dense.rows;
        let mut out = Mat::zeros(rows_sel.len(), k);
        for (r, &i) in rows_sel.iter().enumerate() {
            let (ci, vi) = self.row(i);
            let orow = out.row_mut(r);
            // Gather-style: for each nonzero of the sparse row, axpy into
            // the output row over the dense column — but dense is row-major
            // by landmark, so instead do per-landmark dots with index gather.
            for (j, o) in orow.iter_mut().enumerate() {
                let drow = dense.row(j);
                let mut s = 0.0f32;
                for (&c, &v) in ci.iter().zip(vi) {
                    s += v * drow[c as usize];
                }
                *o = s;
            }
        }
        out
    }

    /// Select a subset of rows into a new sparse matrix.
    pub fn select_rows(&self, idx: &[usize]) -> SparseMatrix {
        let mut out = SparseMatrix::empty(self.cols);
        let mut buf = Vec::new();
        for &i in idx {
            buf.clear();
            let (c, v) = self.row(i);
            buf.extend(c.iter().copied().zip(v.iter().copied()));
            out.push_row(&buf);
        }
        out
    }

    /// Fraction of explicitly stored entries.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

/// Merge-join dot product of two sorted sparse vectors.
#[inline]
pub fn sparse_dot(ci: &[u32], vi: &[f32], cj: &[u32], vj: &[f32]) -> f32 {
    let (mut a, mut b) = (0usize, 0usize);
    let mut s = 0.0f32;
    while a < ci.len() && b < cj.len() {
        let (ca, cb) = (ci[a], cj[b]);
        if ca == cb {
            s += vi[a] * vj[b];
            a += 1;
            b += 1;
        } else if ca < cb {
            a += 1;
        } else {
            b += 1;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SparseMatrix {
        SparseMatrix::from_rows(
            5,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![],
                vec![(0, -1.0), (2, 1.0), (4, 0.5)],
            ],
        )
    }

    #[test]
    fn shape_and_nnz() {
        let m = sample();
        assert_eq!((m.rows, m.cols, m.nnz()), (4, 5, 6));
        assert!((m.density() - 6.0 / 20.0).abs() < 1e-12);
    }

    #[test]
    fn row_access() {
        let m = sample();
        let (c, v) = m.row(0);
        assert_eq!(c, &[0, 2]);
        assert_eq!(v, &[1.0, 2.0]);
        let (c2, _) = m.row(2);
        assert!(c2.is_empty());
    }

    #[test]
    fn row_dot_merge_join() {
        let m = sample();
        // row0 · row3 = 1*(-1) + 2*1 = 1
        assert_eq!(m.row_dot(0, &m, 3), 1.0);
        // row1 · row0 = 0 (disjoint support)
        assert_eq!(m.row_dot(1, &m, 0), 0.0);
        // empty row
        assert_eq!(m.row_dot(2, &m, 3), 0.0);
    }

    #[test]
    fn row_entries_roundtrip() {
        let m = sample();
        assert_eq!(m.row_entries(0), vec![(0, 1.0), (2, 2.0)]);
        assert!(m.row_entries(2).is_empty());
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        let back = SparseMatrix::from_dense(&d);
        assert_eq!(back.to_dense(), d);
        assert_eq!(back.nnz(), m.nnz());
    }

    #[test]
    fn row_sq_norms_match_dense() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(m.row_sq_norms(), d.row_sq_norms());
    }

    #[test]
    fn select_matmul_dense_t_matches_dense() {
        let m = sample();
        let dense = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32 * 0.1 - 0.6);
        let got = m.select_matmul_dense_t(&[0, 3, 2], &dense);
        let want = m.to_dense().select_rows(&[0, 3, 2]).matmul_nt(&dense);
        assert!(got.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn select_rows_preserves_content() {
        let m = sample();
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0).0, m.row(3).0);
        assert_eq!(s.row(1).1, m.row(1).1);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_column_panics() {
        let mut m = SparseMatrix::empty(3);
        m.push_row(&[(5, 1.0)]);
    }
}
