//! Out-of-core data plane: fixed-memory block streaming over a
//! [`DataSource`].
//!
//! Every consumer that used to demand a resident [`Dataset`] — stage-1
//! landmark gather, the blockwise CD solver, streaming evaluation — now
//! pulls the feature matrix through [`DataSource::for_each_block`]: the
//! source delivers the wanted rows in ascending global order, chunked
//! into blocks whose estimated footprint respects a caller-chosen byte
//! budget. Labels are always resident (they are 4 bytes/row and every
//! layer needs them for fold assignment and OVO pair selection); only
//! features stream.
//!
//! ## Stripes: the block-size-independence contract
//!
//! The repo's bit-identity contract extends to this layer: training
//! blockwise must equal training in-memory *byte for byte at any block
//! budget*. Blocks are therefore cut only at global **stripe**
//! boundaries (stripes are fixed windows of [`STRIPE_ROWS`] consecutive
//! global row ids), and every consumer does its per-row work — factor
//! chunk evaluation, visit-order shuffling — per stripe, never per
//! block. A stripe's rows always arrive inside one block, so the
//! computation on a stripe sees identical inputs whether the epoch
//! streamed one block or fifty; the block boundary is purely an I/O
//! artifact. Budgets are soft by one stripe: a block may overshoot the
//! budget by the stripe that crossed it.
//!
//! Two sources implement the trait: [`MemorySource`] wraps a resident
//! [`Dataset`] (blocks are index windows, nothing is copied) and
//! [`ShardedSource`] re-parses LIBSVM shard files per epoch, holding
//! only the current block's features in memory. A budget of `0` means
//! unlimited — one block containing every wanted row, which is the
//! in-memory reference the CI smoke compares the bounded runs against.

use crate::data::dataset::Dataset;
use crate::data::libsvm;
use crate::data::sparse::SparseMatrix;
use anyhow::{bail, Context, Result};
use std::io::BufRead;
use std::path::{Path, PathBuf};

/// Rows per stripe. Blocks are cut only at multiples of this, and all
/// per-row computation downstream is organised per stripe, which is what
/// makes results independent of the block budget (see module docs).
pub const STRIPE_ROWS: usize = 1024;

/// Stripe id of a global row.
#[inline]
pub fn stripe_of(row: usize) -> usize {
    row / STRIPE_ROWS
}

/// Estimated resident footprint of one sparse row with `nnz` stored
/// entries: CSR value + index (8 bytes/entry) plus fixed per-row
/// bookkeeping. An estimate, not an accounting — the RSS assertion in CI
/// carries slack for allocator overhead and parse transients.
#[inline]
pub fn row_cost_bytes(nnz: usize) -> usize {
    16 + 8 * nnz
}

/// One delivered block: a window of wanted rows, in ascending global
/// order, backed by a feature matrix that is only guaranteed to live for
/// the duration of the callback.
pub struct Block<'a> {
    /// Global row ids of the delivered rows, strictly ascending.
    pub rows: &'a [usize],
    /// `x`-row index of each delivered row (`x.row(local[k])` is the
    /// feature row of global row `rows[k]`).
    pub local: &'a [usize],
    /// Feature storage for this block. For [`MemorySource`] this is the
    /// whole resident matrix; for [`ShardedSource`] it holds exactly the
    /// delivered rows.
    pub x: &'a SparseMatrix,
}

impl Block<'_> {
    /// Split the delivered rows into per-stripe index ranges:
    /// `(stripe_id, start, end)` with `rows[start..end]` all in that
    /// stripe. Consumers iterate these instead of the raw block so their
    /// work units are budget-independent.
    pub fn stripes(&self) -> Vec<(usize, usize, usize)> {
        stripe_ranges(self.rows)
    }
}

/// Group ascending global row ids into per-stripe `(stripe_id, start,
/// end)` ranges.
pub fn stripe_ranges(rows: &[usize]) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < rows.len() {
        let sid = stripe_of(rows[start]);
        let mut end = start + 1;
        while end < rows.len() && stripe_of(rows[end]) == sid {
            end += 1;
        }
        out.push((sid, start, end));
        start = end;
    }
    out
}

/// A training-data provider that can stream its feature rows in
/// fixed-memory blocks. Labels and shape are always cheap (resident);
/// features may cost a re-parse per pass.
pub trait DataSource {
    /// Total number of data rows.
    fn n_rows(&self) -> usize;
    /// Feature dimensionality (max column bound across all rows).
    fn n_cols(&self) -> usize;
    /// Number of distinct classes (labels are `0..n_classes`).
    fn n_classes(&self) -> usize;
    /// Class id per row, the same remap [`libsvm::parse`] applies.
    fn labels(&self) -> &[u32];
    /// Human-readable source name (file/dir path or dataset name).
    fn name(&self) -> &str;
    /// Stream the wanted rows in ascending global order, cut into blocks
    /// of roughly `budget_bytes` (0 = unlimited, a single block). When
    /// `wanted` is `Some`, only rows with `wanted[g] == true` are
    /// delivered (the mask must cover all `n_rows`); sources use it to
    /// skip whole shards with no wanted rows. Block boundaries land only
    /// on stripe boundaries and carry no information — consumers must
    /// produce identical results for any budget.
    fn for_each_block(
        &self,
        budget_bytes: usize,
        wanted: Option<&[bool]>,
        f: &mut dyn FnMut(&Block<'_>) -> Result<()>,
    ) -> Result<()>;
}

fn check_mask(wanted: Option<&[bool]>, n_rows: usize) -> Result<()> {
    if let Some(w) = wanted {
        anyhow::ensure!(
            w.len() == n_rows,
            "row mask covers {} rows but the source has {n_rows}",
            w.len()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// In-memory source

/// [`DataSource`] over a resident [`Dataset`]: blocks are index windows
/// into the existing matrix, so streaming adds no copies — the classic
/// in-RAM path expressed through the out-of-core interface.
pub struct MemorySource<'a> {
    ds: &'a Dataset,
}

impl<'a> MemorySource<'a> {
    pub fn new(ds: &'a Dataset) -> MemorySource<'a> {
        MemorySource { ds }
    }
}

impl DataSource for MemorySource<'_> {
    fn n_rows(&self) -> usize {
        self.ds.len()
    }
    fn n_cols(&self) -> usize {
        self.ds.dim()
    }
    fn n_classes(&self) -> usize {
        self.ds.n_classes
    }
    fn labels(&self) -> &[u32] {
        &self.ds.labels
    }
    fn name(&self) -> &str {
        &self.ds.name
    }

    fn for_each_block(
        &self,
        budget_bytes: usize,
        wanted: Option<&[bool]>,
        f: &mut dyn FnMut(&Block<'_>) -> Result<()>,
    ) -> Result<()> {
        check_mask(wanted, self.ds.len())?;
        let mut rows: Vec<usize> = Vec::new();
        let mut bytes = 0usize;
        for g in 0..self.ds.len() {
            if budget_bytes > 0 && g % STRIPE_ROWS == 0 && bytes >= budget_bytes && !rows.is_empty()
            {
                f(&Block { rows: &rows, local: &rows, x: &self.ds.x })?;
                rows.clear();
                bytes = 0;
            }
            let want = match wanted {
                Some(w) => w[g],
                None => true,
            };
            if want {
                let nnz = self.ds.x.indptr[g + 1] - self.ds.x.indptr[g];
                bytes += row_cost_bytes(nnz);
                rows.push(g);
            }
        }
        if !rows.is_empty() {
            f(&Block { rows: &rows, local: &rows, x: &self.ds.x })?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Sharded LIBSVM source

struct ShardMeta {
    path: PathBuf,
    start_row: usize,
    n_rows: usize,
}

/// [`DataSource`] over a directory of LIBSVM shard files (`*.svm`,
/// processed in sorted filename order — the order `lpdsvm split`
/// produces, so shard concatenation is the original file).
///
/// [`ShardedSource::open`] makes one cheap label pass per shard: labels
/// and column bounds parse, feature *values* don't. That yields the
/// resident metadata (labels, shapes, per-shard row spans) that folds
/// and OVO pair selection need, without ever loading features. Each
/// [`DataSource::for_each_block`] pass then re-parses shard bytes,
/// materializing only wanted rows and holding at most one block of
/// features; shard files whose row span contains no wanted rows are
/// skipped without opening them. Feature values of rows that are never
/// wanted are never validated — corruption there surfaces on the first
/// pass that wants the row.
pub struct ShardedSource {
    shards: Vec<ShardMeta>,
    labels: Vec<u32>,
    n_cols: usize,
    n_classes: usize,
    name: String,
}

impl ShardedSource {
    /// Scan `dir` for `*.svm` shards and run the label pass.
    pub fn open(dir: &Path) -> Result<ShardedSource> {
        crate::util::fault::point("data.load")?;
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("opening shard directory {}", dir.display()))?;
        let mut paths: Vec<PathBuf> = Vec::new();
        for entry in entries {
            let path = entry?.path();
            if path.extension().is_some_and(|e| e == "svm") {
                paths.push(path);
            }
        }
        paths.sort();
        if paths.is_empty() {
            bail!("no .svm shard files in {}", dir.display());
        }
        let mut raw_labels: Vec<i64> = Vec::new();
        let mut shards = Vec::with_capacity(paths.len());
        let mut max_col = 0u32;
        let mut line = String::new();
        for path in paths {
            let start_row = raw_labels.len();
            let file = std::fs::File::open(&path)
                .with_context(|| format!("opening shard {}", path.display()))?;
            let mut reader = std::io::BufReader::new(file);
            let mut lineno = 0usize;
            loop {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    break;
                }
                lineno += 1;
                let parsed = libsvm::parse_label(&line, lineno)
                    .with_context(|| format!("scanning shard {}", path.display()))?;
                let Some((label, rest)) = parsed else { continue };
                let cols = libsvm::scan_max_index(rest, lineno)
                    .with_context(|| format!("scanning shard {}", path.display()))?;
                max_col = max_col.max(cols);
                raw_labels.push(label);
            }
            let n_rows = raw_labels.len() - start_row;
            shards.push(ShardMeta { path, start_row, n_rows });
        }
        if raw_labels.is_empty() {
            bail!("shard files in {} contain no data rows", dir.display());
        }
        let map = libsvm::build_label_map(&raw_labels);
        let labels = raw_labels.iter().map(|l| map[l]).collect();
        let n_classes = map.len().max(1);
        Ok(ShardedSource {
            shards,
            labels,
            n_cols: max_col as usize,
            n_classes,
            name: dir.display().to_string(),
        })
    }

    /// Number of shard files.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

/// Flush the sharded builder state as one block (no-op when empty).
fn emit_sharded(
    n_cols: usize,
    rows: &mut Vec<usize>,
    parsed: &mut Vec<Vec<(u32, f32)>>,
    bytes: &mut usize,
    f: &mut dyn FnMut(&Block<'_>) -> Result<()>,
) -> Result<()> {
    if rows.is_empty() {
        return Ok(());
    }
    let x = SparseMatrix::from_rows(n_cols, parsed);
    let local: Vec<usize> = (0..rows.len()).collect();
    f(&Block { rows, local: &local, x: &x })?;
    rows.clear();
    parsed.clear();
    *bytes = 0;
    Ok(())
}

impl DataSource for ShardedSource {
    fn n_rows(&self) -> usize {
        self.labels.len()
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }
    fn n_classes(&self) -> usize {
        self.n_classes
    }
    fn labels(&self) -> &[u32] {
        &self.labels
    }
    fn name(&self) -> &str {
        &self.name
    }

    fn for_each_block(
        &self,
        budget_bytes: usize,
        wanted: Option<&[bool]>,
        f: &mut dyn FnMut(&Block<'_>) -> Result<()>,
    ) -> Result<()> {
        check_mask(wanted, self.labels.len())?;
        let mut rows: Vec<usize> = Vec::new();
        let mut parsed: Vec<Vec<(u32, f32)>> = Vec::new();
        let mut bytes = 0usize;
        let mut line = String::new();
        for shard in &self.shards {
            if let Some(w) = wanted {
                let span = &w[shard.start_row..shard.start_row + shard.n_rows];
                if !span.iter().any(|&b| b) {
                    continue; // whole shard unwanted: never opened
                }
            }
            let file = std::fs::File::open(&shard.path)
                .with_context(|| format!("opening shard {}", shard.path.display()))?;
            let mut reader = std::io::BufReader::new(file);
            let mut lineno = 0usize;
            let mut g = shard.start_row;
            loop {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    break;
                }
                lineno += 1;
                let label = libsvm::parse_label(&line, lineno)
                    .with_context(|| format!("parsing shard {}", shard.path.display()))?;
                let Some((_, rest)) = label else { continue };
                if budget_bytes > 0 && g % STRIPE_ROWS == 0 && bytes >= budget_bytes {
                    emit_sharded(self.n_cols, &mut rows, &mut parsed, &mut bytes, f)?;
                }
                let want = match wanted {
                    Some(w) => w[g],
                    None => true,
                };
                if want {
                    let (entries, _) = libsvm::parse_entries(rest, lineno)
                        .with_context(|| format!("parsing shard {}", shard.path.display()))?;
                    bytes += row_cost_bytes(entries.len());
                    rows.push(g);
                    parsed.push(entries);
                }
                g += 1;
            }
            anyhow::ensure!(
                g - shard.start_row == shard.n_rows,
                "shard {} changed since open: expected {} data rows, found {}",
                shard.path.display(),
                shard.n_rows,
                g - shard.start_row
            );
        }
        emit_sharded(self.n_cols, &mut rows, &mut parsed, &mut bytes, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    /// `n` single-entry rows with distinguishable values, two classes.
    fn toy(n: usize) -> Dataset {
        let rows: Vec<Vec<(u32, f32)>> =
            (0..n).map(|i| vec![((i % 7) as u32, i as f32 * 0.5 + 1.0)]).collect();
        let labels: Vec<u32> = (0..n).map(|i| (i % 2) as u32).collect();
        Dataset::new("toy", SparseMatrix::from_rows(7, &rows), labels, 2)
    }

    fn collect_blocks(src: &dyn DataSource, budget: usize, wanted: Option<&[bool]>) -> (Vec<Vec<usize>>, Mat) {
        let mut blocks = Vec::new();
        let mut dense = Mat::zeros(src.n_rows(), src.n_cols());
        src.for_each_block(budget, wanted, &mut |b: &Block<'_>| {
            blocks.push(b.rows.to_vec());
            for (k, &g) in b.rows.iter().enumerate() {
                let (c, v) = b.x.row(b.local[k]);
                for (&ci, &vi) in c.iter().zip(v) {
                    dense.set(g, ci as usize, vi);
                }
            }
            Ok(())
        })
        .unwrap();
        (blocks, dense)
    }

    #[test]
    fn memory_source_unlimited_budget_is_one_block() {
        let ds = toy(50);
        let src = MemorySource::new(&ds);
        let (blocks, dense) = collect_blocks(&src, 0, None);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0], (0..50).collect::<Vec<_>>());
        assert_eq!(dense.data, ds.x.to_dense().data);
    }

    #[test]
    fn memory_source_cuts_only_at_stripe_boundaries() {
        let ds = toy(2500);
        let src = MemorySource::new(&ds);
        // Each row costs 24 bytes → a stripe is ~24.6 KB; a 30 KB budget
        // forces a cut at the second stripe boundary.
        let (blocks, dense) = collect_blocks(&src, 30_000, None);
        assert!(blocks.len() > 1, "budget should have split the stream");
        let mut all = Vec::new();
        for (i, b) in blocks.iter().enumerate() {
            if i + 1 < blocks.len() {
                // Every cut lands on a stripe boundary.
                assert_eq!((b.last().unwrap() + 1) % STRIPE_ROWS, 0, "{blocks:?}");
            }
            all.extend_from_slice(b);
        }
        assert_eq!(all, (0..2500).collect::<Vec<_>>());
        assert_eq!(dense.data, ds.x.to_dense().data);
    }

    #[test]
    fn wanted_mask_filters_rows() {
        let ds = toy(2500);
        let src = MemorySource::new(&ds);
        let wanted: Vec<bool> = (0..2500).map(|g| g % 3 == 0).collect();
        let (blocks, _) = collect_blocks(&src, 10_000, Some(&wanted));
        let delivered: Vec<usize> = blocks.into_iter().flatten().collect();
        let expect: Vec<usize> = (0..2500).filter(|g| g % 3 == 0).collect();
        assert_eq!(delivered, expect);
    }

    #[test]
    fn stripe_ranges_group_rows() {
        let rows = [0, 5, STRIPE_ROWS - 1, STRIPE_ROWS, 3 * STRIPE_ROWS + 2];
        assert_eq!(
            stripe_ranges(&rows),
            vec![(0, 0, 3), (1, 3, 4), (3, 4, 5)]
        );
        assert!(stripe_ranges(&[]).is_empty());
    }

    fn write_shards(ds: &Dataset, dir: &Path, parts: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let per = ds.len().div_ceil(parts);
        for p in 0..parts {
            let lo = p * per;
            let hi = ((p + 1) * per).min(ds.len());
            let mut text = String::new();
            for i in lo..hi {
                let lbl: i64 = if ds.labels[i] == 1 { 1 } else { -1 };
                text.push_str(&format!("{lbl}"));
                let (c, v) = ds.x.row(i);
                for (&ci, &vi) in c.iter().zip(v) {
                    text.push_str(&format!(" {}:{}", ci + 1, vi));
                }
                text.push('\n');
            }
            std::fs::write(dir.join(format!("part-{p:05}.svm")), text).unwrap();
        }
    }

    #[test]
    fn sharded_source_matches_memory_source() {
        let ds = toy(2500);
        let dir = std::env::temp_dir()
            .join(format!("lpdsvm_block_shards_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_shards(&ds, &dir, 4);
        let sh = ShardedSource::open(&dir).unwrap();
        assert_eq!(sh.n_rows(), ds.len());
        assert_eq!(sh.n_cols(), ds.dim());
        assert_eq!(sh.n_classes(), ds.n_classes);
        assert_eq!(sh.labels(), &ds.labels[..]);
        assert_eq!(sh.n_shards(), 4);
        let mem = MemorySource::new(&ds);
        for budget in [0usize, 10_000, 40_000] {
            let (_, dm) = collect_blocks(&mem, budget, None);
            let (_, dsh) = collect_blocks(&sh, budget, None);
            assert_eq!(dm.data, dsh.data, "budget {budget}");
        }
        // Masked pass: rows from the middle shards only.
        let wanted: Vec<bool> = (0..2500).map(|g| (700..1400).contains(&g)).collect();
        let (_, dm) = collect_blocks(&mem, 5_000, Some(&wanted));
        let (_, dsh) = collect_blocks(&sh, 5_000, Some(&wanted));
        assert_eq!(dm.data, dsh.data);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_rejects_empty_dir() {
        let dir = std::env::temp_dir()
            .join(format!("lpdsvm_block_empty_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = ShardedSource::open(&dir).unwrap_err();
        assert!(err.to_string().contains("no .svm shard files"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
