//! Cross-validation folds.
//!
//! The paper's CV design (§4) fixes the feature representation (landmarks +
//! whitening) ONCE on the full dataset and only then subdivides into folds,
//! so the expensive first stage is shared across all folds. These fold
//! structures therefore index into a shared `G` matrix rather than copying
//! features.

use crate::util::rng::Rng;

/// A k-fold partition of `0..n`, stratified by class label so every fold
/// sees every class (needed for OVO sub-problems inside each fold).
#[derive(Clone, Debug)]
pub struct Folds {
    pub assignments: Vec<u32>, // fold id per point
    pub k: usize,
}

impl Folds {
    /// Stratified k-fold assignment.
    pub fn stratified(labels: &[u32], k: usize, rng: &mut Rng) -> Self {
        assert!(k >= 2, "need at least 2 folds");
        assert!(labels.len() >= k, "fewer points than folds");
        let n_classes = labels.iter().copied().max().map_or(1, |m| m as usize + 1);
        let mut assignments = vec![0u32; labels.len()];
        // Carry the round-robin position across classes instead of
        // restarting every class at fold 0. A fresh restart piles each
        // class's remainder points (count % k) onto the low-numbered
        // folds, and once several classes are smaller than k the high
        // folds can end up empty — which meant empty validation sets in
        // `coordinator::cv`. With the carried offset all n points land on
        // consecutive folds mod k, so total fold sizes differ by at most
        // one and every fold is nonempty whenever n ≥ k.
        let mut start = 0usize;
        for c in 0..n_classes as u32 {
            let mut idx: Vec<usize> = (0..labels.len()).filter(|&i| labels[i] == c).collect();
            rng.shuffle(&mut idx);
            for (pos, &i) in idx.iter().enumerate() {
                assignments[i] = ((start + pos) % k) as u32;
            }
            start = (start + idx.len()) % k;
        }
        Folds { assignments, k }
    }

    /// (train indices, validation indices) for fold `f`.
    pub fn split(&self, f: usize) -> (Vec<usize>, Vec<usize>) {
        assert!(f < self.k);
        let mut train = Vec::new();
        let mut val = Vec::new();
        for (i, &a) in self.assignments.iter().enumerate() {
            if a as usize == f {
                val.push(i);
            } else {
                train.push(i);
            }
        }
        (train, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_partition_everything() {
        let labels: Vec<u32> = (0..100).map(|i| (i % 3) as u32).collect();
        let mut rng = Rng::new(1);
        let folds = Folds::stratified(&labels, 5, &mut rng);
        let mut seen = vec![false; 100];
        for f in 0..5 {
            let (train, val) = folds.split(f);
            assert_eq!(train.len() + val.len(), 100);
            for &i in &val {
                assert!(!seen[i], "point {i} in two validation folds");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn stratification_balances_classes() {
        // 60 of class 0, 30 of class 1, 10 of class 2 across 5 folds.
        let mut labels = vec![0u32; 60];
        labels.extend(vec![1u32; 30]);
        labels.extend(vec![2u32; 10]);
        let mut rng = Rng::new(2);
        let folds = Folds::stratified(&labels, 5, &mut rng);
        for f in 0..5 {
            let (_, val) = folds.split(f);
            let c0 = val.iter().filter(|&&i| labels[i] == 0).count();
            let c1 = val.iter().filter(|&&i| labels[i] == 1).count();
            let c2 = val.iter().filter(|&&i| labels[i] == 2).count();
            assert_eq!(c0, 12);
            assert_eq!(c1, 6);
            assert_eq!(c2, 2);
        }
    }

    #[test]
    fn small_classes_spread_across_all_folds() {
        // Regression: 3 classes × 2 points with k = 5. Restarting every
        // class at fold 0 put all six points on folds {0, 1}, leaving
        // folds 2–4 empty (empty validation sets downstream). The carried
        // offset must fill every fold.
        let labels = vec![0u32, 0, 1, 1, 2, 2];
        let folds = Folds::stratified(&labels, 5, &mut Rng::new(3));
        let mut counts = vec![0usize; 5];
        for &a in &folds.assignments {
            counts[a as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 1), "empty fold: {counts:?}");
        for f in 0..5 {
            let (train, val) = folds.split(f);
            assert!(!val.is_empty(), "fold {f} has an empty validation set");
            assert_eq!(train.len() + val.len(), labels.len());
        }
    }

    #[test]
    fn remainders_do_not_pile_onto_low_folds() {
        // Regression: 4 classes of 5 points with k = 4 leaves remainder 1
        // per class; fresh restarts sent all four spares to fold 0
        // (8 points vs 4 elsewhere). Carried offsets deal one per fold.
        let mut labels: Vec<u32> = Vec::new();
        for c in 0..4u32 {
            labels.extend([c; 5]);
        }
        let folds = Folds::stratified(&labels, 4, &mut Rng::new(5));
        let mut counts = vec![0usize; 4];
        for &a in &folds.assignments {
            counts[a as usize] += 1;
        }
        assert_eq!(counts, vec![5, 5, 5, 5], "unbalanced folds: {counts:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let labels: Vec<u32> = (0..50).map(|i| (i % 2) as u32).collect();
        let a = Folds::stratified(&labels, 4, &mut Rng::new(9));
        let b = Folds::stratified(&labels, 4, &mut Rng::new(9));
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    #[should_panic]
    fn one_fold_rejected() {
        Folds::stratified(&[0, 1, 0, 1], 1, &mut Rng::new(0));
    }
}
