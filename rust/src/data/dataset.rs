//! Labeled dataset: sparse features + integer class labels, with the
//! subset/split operations the coordinator needs (OVO pair extraction,
//! train/test splits, stratified views).

use crate::data::sparse::SparseMatrix;
use crate::util::rng::Rng;

/// A classification dataset. Labels are class ids `0..n_classes`.
/// Binary problems use labels {0, 1} which map to y ∈ {−1, +1}.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: SparseMatrix,
    pub labels: Vec<u32>,
    pub n_classes: usize,
    /// Human-readable name (used in bench tables).
    pub name: String,
}

impl Dataset {
    pub fn new(name: &str, x: SparseMatrix, labels: Vec<u32>, n_classes: usize) -> Self {
        assert_eq!(x.rows, labels.len(), "feature/label count mismatch");
        assert!(
            labels.iter().all(|&l| (l as usize) < n_classes),
            "label out of range"
        );
        Dataset {
            x,
            labels,
            n_classes,
            name: name.to_string(),
        }
    }

    pub fn len(&self) -> usize {
        self.x.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    /// ±1 labels for a binary dataset (n_classes == 2): class 1 → +1.
    pub fn signed_labels(&self) -> Vec<f32> {
        assert_eq!(self.n_classes, 2, "signed_labels needs a binary problem");
        self.labels
            .iter()
            .map(|&l| if l == 1 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Subset by row indices (labels follow).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            n_classes: self.n_classes,
            name: self.name.clone(),
        }
    }

    /// Indices of all points belonging to class `c`.
    pub fn class_indices(&self, c: u32) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.labels[i] == c).collect()
    }

    /// Number of points per class.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Shuffled train/test split with `test_frac` of the points held out.
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_test = ((self.len() as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.subset(train_idx), self.subset(test_idx))
    }

    /// All unordered class pairs `(a, b)`, `a < b` — the OVO sub-problems.
    pub fn class_pairs(&self) -> Vec<(u32, u32)> {
        let c = self.n_classes as u32;
        let mut pairs = Vec::with_capacity((c as usize * (c as usize - 1)) / 2);
        for a in 0..c {
            for b in (a + 1)..c {
                pairs.push((a, b));
            }
        }
        pairs
    }

    /// Extract the binary sub-problem for classes `(a, b)`: points of class
    /// `a` become label 0 (−1), class `b` label 1 (+1). Returns the
    /// sub-dataset and the original row indices.
    pub fn ovo_subproblem(&self, a: u32, b: u32) -> (Dataset, Vec<usize>) {
        let idx: Vec<usize> = (0..self.len())
            .filter(|&i| self.labels[i] == a || self.labels[i] == b)
            .collect();
        let labels: Vec<u32> = idx
            .iter()
            .map(|&i| if self.labels[i] == b { 1 } else { 0 })
            .collect();
        let ds = Dataset {
            x: self.x.select_rows(&idx),
            labels,
            n_classes: 2,
            name: format!("{}[{a}v{b}]", self.name),
        };
        (ds, idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = SparseMatrix::from_rows(
            2,
            &[
                vec![(0, 1.0)],
                vec![(1, 1.0)],
                vec![(0, -1.0)],
                vec![(1, -1.0)],
                vec![(0, 2.0)],
                vec![(1, 2.0)],
            ],
        );
        Dataset::new("toy", x, vec![0, 1, 2, 0, 1, 2], 3)
    }

    #[test]
    fn class_counts_and_indices() {
        let d = toy();
        assert_eq!(d.class_counts(), vec![2, 2, 2]);
        assert_eq!(d.class_indices(1), vec![1, 4]);
    }

    #[test]
    fn ovo_pairs_count() {
        let d = toy();
        assert_eq!(d.class_pairs(), vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn ovo_subproblem_relabels() {
        let d = toy();
        let (sub, idx) = d.ovo_subproblem(0, 2);
        assert_eq!(idx, vec![0, 2, 3, 5]);
        assert_eq!(sub.labels, vec![0, 1, 0, 1]);
        assert_eq!(sub.n_classes, 2);
        assert_eq!(sub.signed_labels(), vec![-1.0, 1.0, -1.0, 1.0]);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::new(1);
        let (train, test) = d.split(0.33, &mut rng);
        assert_eq!(train.len() + test.len(), d.len());
        assert_eq!(test.len(), 2);
    }

    #[test]
    fn subset_follows_labels() {
        let d = toy();
        let s = d.subset(&[5, 0]);
        assert_eq!(s.labels, vec![2, 0]);
        assert_eq!(s.x.row(0).1, d.x.row(5).1);
    }

    #[test]
    #[should_panic]
    fn label_out_of_range_panics() {
        let x = SparseMatrix::from_rows(1, &[vec![(0, 1.0)]]);
        Dataset::new("bad", x, vec![5], 2);
    }
}
