//! Dataset substrate: sparse feature storage, LIBSVM-format I/O, synthetic
//! analogues of the paper's five benchmark datasets, splits and CV folds.
//!
//! Paper role: the paper's table 1 benchmarks (Adult, Epsilon, SUSY,
//! MNIST8M, ImageNet) are reproduced as scale-parameterised synthetic
//! generators ([`synth`]) with the same dimensionality/class structure,
//! read and written in LIBSVM text format ([`libsvm`]) like the
//! originals.
//!
//! Invariants: [`SparseMatrix`] rows keep column indices strictly
//! sorted (kernels and GEMM rely on it); the LIBSVM parser rejects
//! fractional, non-finite, or out-of-range labels with a line number
//! instead of mislabelling silently; [`folds`] assigns every class
//! round-robin across folds with the offset carried *between* classes,
//! so no fold ends up empty and no class piles its remainder onto
//! fold 0.

pub mod block;
pub mod dataset;
pub mod folds;
pub mod libsvm;
pub mod scale;
pub mod sparse;
pub mod synth;

pub use block::{Block, DataSource, MemorySource, ShardedSource};
pub use dataset::Dataset;
pub use sparse::SparseMatrix;
