//! Dataset substrate: sparse feature storage, LIBSVM-format I/O, synthetic
//! analogues of the paper's five benchmark datasets, splits and CV folds.

pub mod dataset;
pub mod folds;
pub mod libsvm;
pub mod scale;
pub mod sparse;
pub mod synth;

pub use dataset::Dataset;
pub use sparse::SparseMatrix;
