//! Property-based testing mini-framework.
//!
//! The offline registry has no `proptest`, so this module provides the
//! subset the test suite needs: composable random generators, a `forall`
//! runner with a fixed case budget, and greedy shrinking of failing
//! inputs.
//!
//! Paper role: the reproduction's correctness claims (parallel ≡ serial
//! bit-identity, solver KKT conditions, round-trip I/O) are checked as
//! properties over randomised inputs rather than single examples —
//! `tests/prop_parallel.rs` is the main consumer.
//!
//! Invariant: deterministic by construction — every case stream is
//! seeded from the property name, so a failure reproduces exactly on
//! re-run with no stored corpus.

pub mod prop;

pub use prop::{forall, Gen};
