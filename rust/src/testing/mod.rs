//! Property-based testing mini-framework.
//!
//! The offline registry has no `proptest`, so this module provides the
//! subset the test suite needs: composable random generators, a `forall`
//! runner with a fixed case budget, and greedy shrinking of failing
//! inputs. Deterministic by construction (seeded from the property name),
//! so failures are reproducible.

pub mod prop;

pub use prop::{forall, Gen};
