//! `forall`-style property runner with shrinking.

use crate::util::rng::Rng;

/// A generator produces a value from an RNG and knows how to shrink a
/// failing value toward smaller counterexamples.
pub struct Gen<T> {
    pub generate: Box<dyn Fn(&mut Rng) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        generate: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            generate: Box::new(generate),
            shrink: Box::new(shrink),
        }
    }

    /// Map the generated value (shrinking is lost; fine for derived gens).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let g = self.generate;
        Gen {
            generate: Box::new(move |rng| f(g(rng))),
            shrink: Box::new(|_| Vec::new()),
        }
    }
}

/// usize in `[lo, hi]`, shrinking toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(
        move |rng| lo + rng.usize(hi - lo + 1),
        move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        },
    )
}

/// f64 in `[lo, hi)`, shrinking toward the midpoint-free simple values.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(
        move |rng| rng.range_f64(lo, hi),
        move |&v| {
            let mut out = Vec::new();
            for cand in [lo, 0.0, 1.0, (lo + hi) / 2.0] {
                if cand >= lo && cand < hi && (cand - v).abs() > 1e-12 {
                    out.push(cand);
                }
            }
            out
        },
    )
}

/// Vec of f32 with length from `len_gen`, entries in `[lo, hi)`. Shrinks by
/// halving the vector and zeroing entries.
pub fn vec_f32(len: Gen<usize>, lo: f32, hi: f32) -> Gen<Vec<f32>> {
    let gen_len = len.generate;
    Gen::new(
        move |rng| {
            let n = gen_len(rng);
            (0..n)
                .map(|_| lo + (hi - lo) * rng.f32())
                .collect::<Vec<f32>>()
        },
        |v| {
            let mut out = Vec::new();
            if v.len() > 1 {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
            if v.iter().any(|&x| x != 0.0) {
                out.push(vec![0.0; v.len()]);
            }
            out
        },
    )
}

/// Outcome of a property check.
pub struct Failure<T> {
    pub original: T,
    pub shrunk: T,
    pub shrink_steps: usize,
    pub message: String,
}

/// Run `prop` on `cases` random inputs; on failure, greedily shrink and
/// panic with both the original and minimised counterexample. The RNG seed
/// derives from `name`, so reruns are deterministic.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    cases: usize,
    gen: &Gen<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = (gen.generate)(&mut rng);
        if let Err(msg) = prop(&input) {
            let failure = shrink_failure(gen, input, msg, &mut prop);
            panic!(
                "property '{name}' failed (case {case}/{cases}):\n  original: {:?}\n  shrunk ({} steps): {:?}\n  error: {}",
                failure.original, failure.shrink_steps, failure.shrunk, failure.message
            );
        }
    }
}

fn shrink_failure<T: Clone + std::fmt::Debug>(
    gen: &Gen<T>,
    original: T,
    first_msg: String,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) -> Failure<T> {
    let mut current = original.clone();
    let mut message = first_msg;
    let mut steps = 0;
    // Greedy descent, bounded to avoid pathological loops.
    'outer: for _ in 0..200 {
        for cand in (gen.shrink)(&current) {
            if let Err(msg) = prop(&cand) {
                current = cand;
                message = msg;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    Failure {
        original,
        shrunk: current,
        shrink_steps: steps,
        message,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("usize-bounds", 200, &usize_in(2, 50), |&n| {
            if (2..=50).contains(&n) {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall("must-fail", 100, &usize_in(0, 100), |&n| {
                if n < 37 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("must-fail"), "{msg}");
        // Shrinker should find a small counterexample (37 or close to it).
        assert!(msg.contains("shrunk"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(
            "vec-bounds",
            100,
            &vec_f32(usize_in(0, 20), -1.0, 1.0),
            |v| {
                if v.len() <= 20 && v.iter().all(|&x| (-1.0..1.0).contains(&x)) {
                    Ok(())
                } else {
                    Err(format!("bad vec {v:?}"))
                }
            },
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        let gen = usize_in(0, 1000);
        forall("det", 10, &gen, |&n| {
            first.push(n);
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        forall("det", 10, &gen, |&n| {
            second.push(n);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
