//! Offline stand-in for the `xla` crate (xla-rs over xla_extension).
//!
//! The build environment has no network registry and no vendored PJRT
//! bindings, so this module mirrors exactly the API surface that
//! [`crate::runtime::client`] and [`crate::runtime::accel`] consume. Every
//! runtime type is an *uninhabited* enum: the only constructors
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) fail with a
//! clear message, which makes all downstream methods statically
//! unreachable (`match *self {}`) while keeping the call sites compiling
//! unchanged. Building with `--features xla` (plus a vendored `xla` path
//! dependency) swaps this stub out for the real bindings — see Cargo.toml.

use std::fmt;

/// Error type standing in for `xla::Error`; only `Display` is consumed.
#[derive(Debug)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str = "xla_extension is not linked in this build \
     (offline stub; rebuild with --features xla and a vendored xla crate, \
     or use the native backend)";

/// PJRT client handle. Never constructible in the stub.
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match *self {}
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        match *self {}
    }
}

/// Device-resident buffer. Never constructible in the stub.
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match *self {}
    }
}

/// Compiled executable. Never constructible in the stub.
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match *self {}
    }
}

/// Host-side literal. Never constructible in the stub.
pub enum Literal {}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        match self {}
    }

    pub fn to_vec(&self) -> Result<Vec<f32>, Error> {
        match *self {}
    }
}

/// Parsed HLO module. Never constructible in the stub.
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Built computation. Never constructible in the stub.
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_hint() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("native backend"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
