//! Artifact registry + PJRT client wrapper.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) lists the
//! lowered shape variants. Interchange is HLO **text**: jax ≥ 0.5 emits
//! HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see aot.py and
//! /opt/xla-example/README.md).

use crate::util::json::Json;
#[cfg(not(feature = "xla"))]
use crate::runtime::xla_stub as xla;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One lowered artifact: a stage-1 chunk computation with static shapes.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Rows per chunk.
    pub m: usize,
    /// Landmark/budget dimension (also the padded output width).
    pub b: usize,
    /// Input feature dimension.
    pub p: usize,
}

/// PJRT client + lazily compiled executables, keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest from an artifacts directory and start a PJRT CPU
    /// client. Fails cleanly if artifacts were never built (`make
    /// artifacts`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in manifest
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .context("manifest.artifacts missing")?
        {
            artifacts.push(ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact.name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .context("artifact.file")?
                    .to_string(),
                m: a.get("m").and_then(|v| v.as_usize()).context("artifact.m")?,
                b: a.get("b").and_then(|v| v.as_usize()).context("artifact.b")?,
                p: a.get("p").and_then(|v| v.as_usize()).context("artifact.p")?,
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest lists no artifacts");
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            artifacts,
            executables: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$LPDSVM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("LPDSVM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The underlying PJRT client (device-buffer uploads etc.).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Smallest stage-1 variant that fits `b` landmarks and `p` features.
    pub fn pick_stage1(&self, b: usize, p: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.name.starts_with("stage1") && a.b >= b && a.p >= p)
            .min_by_key(|a| (a.b, a.p))
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&self, meta: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(&meta.name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", meta.name))?;
        let exe = Rc::new(exe);
        self.executables
            .borrow_mut()
            .insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held in the cache.
    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; fall back to env override.
        Runtime::default_dir()
    }

    #[test]
    fn manifest_loads_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            crate::log_warn!("test", "skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&dir).unwrap();
        assert!(!rt.artifacts().is_empty());
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn pick_smallest_fitting_variant() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            crate::log_warn!("test", "skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&dir).unwrap();
        if let Some(a) = rt.pick_stage1(10, 10) {
            assert!(a.b >= 10 && a.p >= 10);
            // No strictly smaller fitting variant exists.
            for other in rt.artifacts() {
                if other.name.starts_with("stage1") && other.b >= 10 && other.p >= 10 {
                    assert!((a.b, a.p) <= (other.b, other.p));
                }
            }
        }
    }

    #[test]
    fn missing_dir_fails_with_hint() {
        let err = match Runtime::load(Path::new("/nonexistent/artifacts")) {
            Ok(_) => panic!("expected failure"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
