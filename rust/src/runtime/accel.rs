//! `Stage1Backend` implementation over the PJRT runtime.
//!
//! Shape handling: artifacts are lowered at a fixed `(m, b, p)`; inputs are
//! zero-padded up to the chosen variant. Padding is *exact*, not
//! approximate: padded feature columns contribute nothing to inner
//! products or norms, and padded landmark rows are nullified because the
//! corresponding rows of the whitening matrix `W` are zero — the kernel
//! values they produce are multiplied away in `K·W`. Padded chunk rows are
//! simply discarded on the way out.

use crate::data::sparse::SparseMatrix;
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::lowrank::factor::Stage1Backend;
use crate::runtime::client::{ArtifactMeta, Runtime};
#[cfg(not(feature = "xla"))]
use crate::runtime::xla_stub as xla;
use anyhow::{Context, Result};
use std::cell::RefCell;

/// Cached per-factor constants, uploaded ONCE as device buffers (`§Perf`:
/// re-marshalling the b×p landmark literal per chunk dominated dispatch
/// cost for large p — device-resident constants + `execute_b` cut the
/// per-chunk host work to the data chunk itself).
struct ConstCache {
    key: (usize, usize, usize, usize, u64),
    l: xla::PjRtBuffer,
    w: xla::PjRtBuffer,
    gamma: xla::PjRtBuffer,
    meta: ArtifactMeta,
}

/// PJRT-backed stage-1 backend (the paper's "GPU path").
pub struct AccelBackend<'rt> {
    rt: &'rt Runtime,
    cache: RefCell<Option<ConstCache>>,
}

impl<'rt> AccelBackend<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        AccelBackend {
            rt,
            cache: RefCell::new(None),
        }
    }

    /// Pad `src` (r×c, row-major) into an `R×C` zero matrix.
    fn pad(src: &Mat, big_rows: usize, big_cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; big_rows * big_cols];
        for i in 0..src.rows {
            out[i * big_cols..i * big_cols + src.cols].copy_from_slice(src.row(i));
        }
        out
    }

    fn ensure_consts(
        &self,
        landmarks: &Mat,
        whiten: &Mat,
        gamma: f64,
    ) -> Result<(ArtifactMeta, usize)> {
        let key = (
            landmarks.data.as_ptr() as usize,
            landmarks.rows,
            landmarks.cols,
            whiten.cols,
            (gamma as f32).to_bits() as u64,
        );
        if let Some(c) = self.cache.borrow().as_ref() {
            if c.key == key {
                return Ok((c.meta.clone(), c.meta.m));
            }
        }
        let meta = self
            .rt
            .pick_stage1(landmarks.rows, landmarks.cols)
            .with_context(|| {
                format!(
                    "no stage1 artifact fits b={} p={} (available: {:?}) — \
                     rebuild with `make artifacts` or use the native backend",
                    landmarks.rows,
                    landmarks.cols,
                    self.rt
                        .artifacts()
                        .iter()
                        .map(|a| (a.b, a.p))
                        .collect::<Vec<_>>()
                )
            })?
            .clone();
        let client = self.rt.client();
        let upload = |data: &[f32], dims: &[usize]| -> Result<xla::PjRtBuffer> {
            client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("device upload: {e}"))
        };
        let l = upload(&Self::pad(landmarks, meta.b, meta.p), &[meta.b, meta.p])?;
        let w = upload(&Self::pad(whiten, meta.b, meta.b), &[meta.b, meta.b])?;
        let gamma_buf = upload(&[gamma as f32], &[1, 1])?;
        let m = meta.m;
        *self.cache.borrow_mut() = Some(ConstCache {
            key,
            l,
            w,
            gamma: gamma_buf,
            meta,
        });
        Ok((self.cache.borrow().as_ref().unwrap().meta.clone(), m))
    }

    /// Run one padded sub-chunk (≤ meta.m rows) through the executable.
    fn run_subchunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        meta: &ArtifactMeta,
        rank: usize,
    ) -> Result<Mat> {
        // Densify + pad the chunk.
        let mut xbuf = vec![0.0f32; meta.m * meta.p];
        for (r, &i) in rows.iter().enumerate() {
            let (cols, vals) = x.row(i);
            let row = &mut xbuf[r * meta.p..(r + 1) * meta.p];
            for (&c, &v) in cols.iter().zip(vals) {
                row[c as usize] = v;
            }
        }
        let x_buf = self
            .rt
            .client()
            .buffer_from_host_buffer(&xbuf, &[meta.m, meta.p], None)
            .map_err(|e| anyhow::anyhow!("device upload (chunk): {e}"))?;

        let exe = self.rt.executable(meta)?;
        let cache = self.cache.borrow();
        let consts = cache.as_ref().expect("consts cached");
        let args: [&xla::PjRtBuffer; 4] = [&x_buf, &consts.l, &consts.w, &consts.gamma];
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("PJRT execute: {e}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("device→host: {e}"))?;
        let lit = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        let flat: Vec<f32> = lit
            .to_vec()
            .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
        anyhow::ensure!(
            flat.len() == meta.m * meta.b,
            "unexpected output size {} (want {}×{})",
            flat.len(),
            meta.m,
            meta.b
        );
        // Slice out the real rows and the real rank columns.
        let mut out = Mat::zeros(rows.len(), rank);
        for r in 0..rows.len() {
            out.row_mut(r)
                .copy_from_slice(&flat[r * meta.b..r * meta.b + rank]);
        }
        Ok(out)
    }
}

impl<'rt> Stage1Backend for AccelBackend<'rt> {
    fn g_chunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        landmarks: &Mat,
        _landmark_sq: &[f32],
        whiten: &Mat,
        kernel: &Kernel,
    ) -> Result<Mat> {
        let gamma = match *kernel {
            Kernel::Gaussian { gamma } => gamma,
            other => anyhow::bail!(
                "accelerator artifacts are lowered for the Gaussian kernel \
                 (paper's experimental setting); got {:?} — use NativeBackend",
                other
            ),
        };
        let (meta, m) = self.ensure_consts(landmarks, whiten, gamma)?;
        let rank = whiten.cols;
        let mut out = Mat::zeros(rows.len(), rank);
        let mut offset = 0usize;
        for sub in rows.chunks(m) {
            let g = self.run_subchunk(x, sub, &meta, rank)?;
            for r in 0..sub.len() {
                out.row_mut(offset + r).copy_from_slice(g.row(r));
            }
            offset += sub.len();
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{FeatureStyle, SynthSpec};
    use crate::lowrank::factor::NativeBackend;
    use crate::lowrank::{LowRankFactor, Stage1Config};
    use crate::util::timer::StageClock;

    fn artifacts_available() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(&dir).unwrap())
        } else {
            crate::log_warn!("test", "skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn accel_matches_native_g() {
        let Some(rt) = artifacts_available() else { return };
        let x = SynthSpec {
            name: "t".into(),
            n: 150,
            p: 20,
            n_classes: 2,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 31,
        }
        .generate()
        .x;
        let cfg = Stage1Config {
            budget: 24,
            chunk: 64,
            ..Default::default()
        };
        let kernel = Kernel::gaussian(0.1);
        let mut clock = StageClock::new();
        let f_native =
            LowRankFactor::compute(&x, kernel, &cfg, &NativeBackend::default(), &mut clock).unwrap();
        let accel = AccelBackend::new(&rt);
        let mut clock2 = StageClock::new();
        let f_accel = LowRankFactor::compute(&x, kernel, &cfg, &accel, &mut clock2).unwrap();
        assert_eq!(f_native.g.rows, f_accel.g.rows);
        assert_eq!(f_native.g.cols, f_accel.g.cols);
        let diff = f_native.g.max_abs_diff(&f_accel.g);
        assert!(diff < 1e-3, "native vs PJRT G differ by {diff}");
    }

    #[test]
    fn accel_rejects_non_gaussian() {
        let Some(rt) = artifacts_available() else { return };
        let accel = AccelBackend::new(&rt);
        let x = SparseMatrix::from_rows(2, &[vec![(0, 1.0)]]);
        let lm = Mat::zeros(1, 2);
        let w = Mat::zeros(1, 1);
        let err = accel
            .g_chunk(&x, &[0], &lm, &[0.0], &w, &Kernel::Linear)
            .unwrap_err();
        assert!(format!("{err}").contains("Gaussian"));
    }

    #[test]
    fn accel_handles_oversized_chunks() {
        // rows.len() > artifact m must be split internally.
        let Some(rt) = artifacts_available() else { return };
        let x = SynthSpec {
            name: "t".into(),
            n: 600,
            p: 10,
            n_classes: 2,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 32,
        }
        .generate()
        .x;
        let cfg = Stage1Config {
            budget: 16,
            chunk: 600, // force one giant chunk > m
            ..Default::default()
        };
        let kernel = Kernel::gaussian(0.2);
        let accel = AccelBackend::new(&rt);
        let mut clock = StageClock::new();
        let f = LowRankFactor::compute(&x, kernel, &cfg, &accel, &mut clock).unwrap();
        let mut clock2 = StageClock::new();
        let f_native =
            LowRankFactor::compute(&x, kernel, &cfg, &NativeBackend::default(), &mut clock2).unwrap();
        assert!(f.g.max_abs_diff(&f_native.g) < 1e-3);
    }
}
