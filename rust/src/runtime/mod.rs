//! PJRT runtime — the "accelerator" path.
//!
//! Loads the HLO-text artifacts that `python/compile/aot.py` lowered from
//! the JAX+Pallas stage-1 graph, compiles them once per shape variant on
//! the PJRT CPU client (the stand-in for the paper's CUDA devices — see
//! DESIGN.md §Hardware-Adaptation), and exposes them as a
//! [`crate::lowrank::Stage1Backend`] so the rest of the system is
//! backend-agnostic. Python never runs at request time; the artifacts are
//! self-contained HLO.
//!
//! Invariants: artifact lookup is shape-exact (a missing `(m, b, p)`
//! variant is a clear error, never a silent recompile); each executable
//! is compiled once per process and reused; without the `xla` feature
//! the stub keeps `cargo build` green and fails at *runtime* with an
//! actionable message.

pub mod accel;
pub mod client;
#[cfg(not(feature = "xla"))]
pub mod xla_stub;

// The feature only removes the stub; it cannot supply the real bindings
// by itself. Fail with an actionable message instead of unresolved-module
// errors at every `xla::` path.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires real PJRT bindings: add a vendored `xla` \
     path dependency to rust/Cargo.toml (the crate is not on the offline \
     registry), then remove this guard"
);

pub use accel::AccelBackend;
pub use client::{ArtifactMeta, Runtime};
