//! Stage 1 of LPD-SVM: the low-rank feature construction.
//!
//! Pipeline (paper figure 1): sample `B` landmarks → compute `K_BB` →
//! eigendecompose → drop eigenvalues below `ε·λ_max` → whitening map
//! `W = V_r Λ_r^{-1/2}` → fully precompute `G = K_nB W` (n×r) held in RAM
//! — the paper's "more RAM" ingredient ([`memory`] plans the budget).
//!
//! Invariants: the factor depends only on the kernel parameter and seed
//! (so CV/grid share it); the whitening map keeps only the positive
//! spectrum (rank follows `whiten.cols`, no near-singular blowups); the
//! chunked computation is bit-identical across chunk sizes, thread
//! counts, and backends' serial paths (`tests/prop_parallel.rs`).

pub mod factor;
pub mod landmarks;
pub mod memory;
pub mod stream;

pub use factor::{LowRankFactor, Stage1Backend, Stage1Config};
pub use memory::{max_affordable_budget, MemoryPlan};
pub use stream::StreamFactor;
