//! Landmark (basis) selection for the Nyström subspace.
//!
//! The paper settles on a fixed random sample of training points (§4): it
//! precludes merging-style budget maintenance but enables complete
//! precomputation of `G`. We also provide a k-means++-style diverse
//! sampler as an optional improvement (the paper cites data-dependent
//! subspaces [26] as the motivation for Nyström over random features).

use crate::data::sparse::SparseMatrix;
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Landmark selection strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LandmarkStrategy {
    /// Uniform random subset of the training points (paper default).
    Uniform,
    /// Greedy kernel k-means++ seeding: each landmark picked with
    /// probability proportional to its squared kernel distance from the
    /// span of already-chosen landmarks (approximated by min distance).
    KmeansPlusPlus,
}

/// Select `b` landmark row indices from `x`.
pub fn select(
    x: &SparseMatrix,
    b: usize,
    strategy: LandmarkStrategy,
    kernel: &Kernel,
    rng: &mut Rng,
) -> Vec<usize> {
    let b = b.min(x.rows);
    match strategy {
        LandmarkStrategy::Uniform => {
            let mut idx = rng.sample_indices(x.rows, b);
            idx.sort_unstable();
            idx
        }
        LandmarkStrategy::KmeansPlusPlus => kmeanspp(x, b, kernel, rng),
    }
}

fn kmeanspp(x: &SparseMatrix, b: usize, kernel: &Kernel, rng: &mut Rng) -> Vec<usize> {
    let n = x.rows;
    // Subsample candidates for tractability on large n.
    let n_cand = (b * 16).min(n);
    let cand = rng.sample_indices(n, n_cand);
    let mut chosen = vec![cand[rng.usize(n_cand)]];
    // d2[i] = min over chosen c of kernel distance^2 between cand[i] and c:
    // ||φ(x)-φ(c)||² = k(x,x) + k(c,c) − 2 k(x,c).
    let mut d2 = vec![f32::MAX; n_cand];
    while chosen.len() < b {
        let last = *chosen.last().unwrap();
        let mut total = 0.0f64;
        for (i, &ci) in cand.iter().enumerate() {
            let kxx = kernel.diag(x.row_sq_norm(ci));
            let kcc = kernel.diag(x.row_sq_norm(last));
            let kxc = kernel.eval_sparse(x, ci, x, last);
            let d = (kxx + kcc - 2.0 * kxc).max(0.0);
            if d < d2[i] {
                d2[i] = d;
            }
            total += d2[i] as f64;
        }
        if total <= 0.0 {
            // Degenerate: all candidates coincide with chosen set; fall back
            // to uniform fill.
            for &ci in &cand {
                if !chosen.contains(&ci) {
                    chosen.push(ci);
                    if chosen.len() == b {
                        break;
                    }
                }
            }
            break;
        }
        let mut target = rng.f64() * total;
        let mut pick = cand[0];
        for (i, &ci) in cand.iter().enumerate() {
            target -= d2[i] as f64;
            if target <= 0.0 {
                pick = ci;
                break;
            }
        }
        if !chosen.contains(&pick) {
            chosen.push(pick);
        } else if let Some(&alt) = cand.iter().find(|c| !chosen.contains(c)) {
            chosen.push(alt);
        } else {
            break;
        }
    }
    chosen.sort_unstable();
    chosen.dedup();
    chosen
}

/// Densify the selected landmark rows into a `B×p` matrix with
/// precomputed squared norms — the representation both backends consume.
/// Serial entry point, identical to [`densify_threads`] with one thread.
pub fn densify(x: &SparseMatrix, idx: &[usize]) -> (Mat, Vec<f32>) {
    densify_threads(x, idx, 1)
}

/// Parallel densify: landmark rows are scattered into disjoint row bands
/// of the output matrix (bit-identical for every thread count).
pub fn densify_threads(x: &SparseMatrix, idx: &[usize], threads: usize) -> (Mat, Vec<f32>) {
    let cols = x.cols;
    let mut m = Mat::zeros(idx.len(), cols);
    crate::util::threads::parallel_chunks(&mut m.data, cols, threads, |rows, band| {
        for (bi, r) in rows.enumerate() {
            let (c, v) = x.row(idx[r]);
            let row = &mut band[bi * cols..(bi + 1) * cols];
            for (&ci, &vi) in c.iter().zip(v) {
                row[ci as usize] = vi;
            }
        }
    });
    let sq = m.row_sq_norms();
    (m, sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{FeatureStyle, SynthSpec};

    fn data(n: usize) -> SparseMatrix {
        SynthSpec {
            name: "t".into(),
            n,
            p: 12,
            n_classes: 2,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed: 3,
        }
        .generate()
        .x
    }

    #[test]
    fn uniform_selects_distinct_sorted() {
        let x = data(100);
        let mut rng = Rng::new(1);
        let idx = select(&x, 20, LandmarkStrategy::Uniform, &Kernel::gaussian(0.1), &mut rng);
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn budget_capped_at_n() {
        let x = data(10);
        let mut rng = Rng::new(1);
        let idx = select(&x, 50, LandmarkStrategy::Uniform, &Kernel::gaussian(0.1), &mut rng);
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn kmeanspp_selects_enough_distinct() {
        let x = data(200);
        let mut rng = Rng::new(2);
        let idx = select(
            &x,
            16,
            LandmarkStrategy::KmeansPlusPlus,
            &Kernel::gaussian(0.2),
            &mut rng,
        );
        assert!(idx.len() >= 15, "got {}", idx.len());
        let mut d = idx.clone();
        d.dedup();
        assert_eq!(d.len(), idx.len());
    }

    #[test]
    fn densify_matches_rows() {
        let x = data(30);
        let (m, sq) = densify(&x, &[3, 17]);
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 12);
        assert!((sq[0] - x.row_sq_norm(3)).abs() < 1e-5);
        let dense = x.to_dense();
        assert_eq!(m.row(1), dense.row(17));
    }
}
