//! The low-rank factor `G` — complete precomputation ("more RAM!").
//!
//! `G = K_nB · W` with `W = V_r Λ_r^{-1/2}` from the eigendecomposition of
//! the landmark matrix `K_BB`, truncated at `ε·λ_max` (the paper's adaptive
//! rank reduction for numerically noisy eigendirections). `G Gᵀ` is the
//! Nyström approximation of the full kernel matrix, so stage 2 reduces to a
//! *linear* SVM over the rows of `G`.
//!
//! Assembly is chunked: both the native backend (Rust GEMM) and the
//! accelerator backend (AOT-compiled JAX+Pallas artifact via PJRT) consume
//! fixed-size row chunks, mirroring the paper's streaming design for
//! "G fits in CPU RAM but not GPU RAM".

use crate::data::sparse::SparseMatrix;
use crate::kernel::Kernel;
use crate::linalg::eigen::sym_eig_threads;
use crate::linalg::Mat;
use crate::lowrank::landmarks::{self, LandmarkStrategy};
use crate::util::rng::Rng;
use crate::util::timer::StageClock;

/// Stage-1 configuration.
#[derive(Clone, Debug)]
pub struct Stage1Config {
    /// Budget B: number of landmark points.
    pub budget: usize,
    /// Relative eigenvalue threshold ε: drop λ < ε·λ_max. The paper drops
    /// "components close to machine precision times the largest
    /// eigenvalue"; 1e-6 is a robust default for f32 storage.
    pub eps_rank: f64,
    /// Row-chunk size for streaming assembly.
    pub chunk: usize,
    pub strategy: LandmarkStrategy,
    pub seed: u64,
    /// Worker threads for the stage-1 compute backbone (landmark densify,
    /// `K_BB` assembly, the parallel Jacobi eigensolver; the per-chunk
    /// kernel block and GEMM are governed by the backend's own thread
    /// count). All of it runs on the shared persistent pool
    /// (`util::threads::global`). 0 = auto (`LPDSVM_THREADS` or all
    /// cores). The parallel path is bit-identical to `threads == 1`.
    pub threads: usize,
}

impl Default for Stage1Config {
    fn default() -> Self {
        Stage1Config {
            budget: 512,
            eps_rank: 1e-6,
            chunk: 256,
            strategy: LandmarkStrategy::Uniform,
            seed: 0x5eed,
            threads: 0,
        }
    }
}

impl Stage1Config {
    /// Resolve `threads == 0` to the environment default.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::threads::default_threads()
        } else {
            self.threads
        }
    }

    /// Copy of this config with `threads == 0` replaced by `fallback` —
    /// how coordinators flow their resolved thread budget into stage 1
    /// without overriding an explicitly pinned count.
    pub fn with_thread_fallback(&self, fallback: usize) -> Stage1Config {
        let mut cfg = self.clone();
        if cfg.threads == 0 {
            cfg.threads = fallback;
        }
        cfg
    }
}

/// Backend that turns a row chunk into its `G` chunk. `Native` runs the
/// Rust GEMM path; implementations in `runtime::accel` run the AOT
/// JAX+Pallas artifact on the PJRT client (the paper's "GPU path").
// NOTE: deliberately NOT `Sync` — the PJRT-backed implementation wraps raw
// C pointers. Stage-1 chunks are processed sequentially per factor; the
// native backend parallelises *inside* each chunk (row-banded kernel
// block + GEMM), and pair-level parallelism happens above this layer on
// plain `Mat` data.
pub trait Stage1Backend {
    /// Compute `K(X[rows], L) @ W` for one chunk.
    /// `x_sq[r]` are the squared norms of the selected rows.
    fn g_chunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        landmarks: &Mat,
        landmark_sq: &[f32],
        whiten: &Mat,
        kernel: &Kernel,
    ) -> anyhow::Result<Mat>;

    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (the paper's CPU path: Eigen + OpenMP there, our
/// tiled GEMM over the shared persistent worker pool here — every
/// `NativeBackend` submits to the same lazily-spawned
/// [`crate::util::threads::global`] pool, so pool-side compute threads
/// stay fixed no matter how many backends are live). `threads` caps the
/// row-band parallelism of the per-chunk kernel block and the `K·W`
/// product: 0 = auto (`LPDSVM_THREADS` or all cores), 1 = the serial
/// reference path. Any thread count produces bit-identical chunks.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend {
    pub threads: usize,
}

impl NativeBackend {
    /// Single-threaded backend — the differential-testing reference.
    /// Outer job farms no longer need this to avoid oversubscription:
    /// pooled backends share the process-wide worker pool, which bounds
    /// total compute threads by itself.
    pub fn serial() -> NativeBackend {
        NativeBackend { threads: 1 }
    }

    /// Backend with an explicit thread count (0 = auto).
    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend { threads }
    }

    fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::threads::default_threads()
        } else {
            self.threads
        }
    }
}

impl Stage1Backend for NativeBackend {
    fn g_chunk(
        &self,
        x: &SparseMatrix,
        rows: &[usize],
        landmarks: &Mat,
        landmark_sq: &[f32],
        whiten: &Mat,
        kernel: &Kernel,
    ) -> anyhow::Result<Mat> {
        let threads = self.effective_threads();
        let k_block = kernel.block_threads(x, rows, landmarks, landmark_sq, threads);
        Ok(k_block.matmul_threads(whiten, threads))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The fully precomputed low-rank representation (stage-1 output).
#[derive(Clone, Debug)]
pub struct LowRankFactor {
    /// `G` — n × rank, row i is the feature vector of training point i.
    pub g: Mat,
    /// Dense landmark matrix (B × p) and its squared row norms.
    pub landmarks: Mat,
    pub landmark_sq: Vec<f32>,
    /// Whitening map `W = V_r Λ_r^{-1/2}` (B × rank).
    pub whiten: Mat,
    /// Effective rank after eigenvalue truncation (= G.cols).
    pub rank: usize,
    /// Eigenvalues of `K_BB` (descending, full length B).
    pub eigenvalues: Vec<f64>,
    pub kernel: Kernel,
    /// Indices of the landmark rows in the source dataset.
    pub landmark_idx: Vec<usize>,
}

impl LowRankFactor {
    /// Run stage 1: select landmarks, factor `K_BB`, assemble `G`.
    /// Stage timings are accumulated into `clock` under the paper's
    /// figure-3 stage names: "preparation" (landmarks + K_BB + eigh) and
    /// "matrix_g" (chunked assembly).
    pub fn compute(
        x: &SparseMatrix,
        kernel: Kernel,
        cfg: &Stage1Config,
        backend: &dyn Stage1Backend,
        clock: &mut StageClock,
    ) -> anyhow::Result<LowRankFactor> {
        anyhow::ensure!(x.rows > 0, "empty dataset");
        let mut rng = Rng::new(cfg.seed);
        let threads = cfg.effective_threads();

        // --- preparation: landmarks, K_BB, eigendecomposition ---
        let (landmark_idx, lm, lm_sq, eig, rank, whiten) = clock.time("preparation", || {
            let landmark_idx = landmarks::select(x, cfg.budget, cfg.strategy, &kernel, &mut rng);
            let (lm, lm_sq) = landmarks::densify_threads(x, &landmark_idx, threads);
            let k_bb = kernel.symmetric_matrix_threads(&lm, &lm_sq, threads);
            // Parallel tournament Jacobi: same result for every thread
            // count, so the factor stays bit-identical across `threads`.
            let eig = sym_eig_threads(&k_bb, 40, 1e-12, threads);
            let whiten = eig.whitening_map(eig.effective_rank(cfg.eps_rank));
            // `whitening_map` clamps to the positive spectrum, so on a
            // degenerate (all non-positive) K_BB the factor honestly has
            // rank 0 instead of one 1e154-scaled poison column.
            let rank = whiten.cols;
            (landmark_idx, lm, lm_sq, eig, rank, whiten)
        });

        // --- matrix G: chunked assembly through the backend ---
        let g = clock.time("matrix_g", || -> anyhow::Result<Mat> {
            let mut g = Mat::zeros(x.rows, rank);
            let rows_all: Vec<usize> = (0..x.rows).collect();
            for chunk in rows_all.chunks(cfg.chunk.max(1)) {
                let gc = backend.g_chunk(x, chunk, &lm, &lm_sq, &whiten, &kernel)?;
                debug_assert_eq!(gc.rows, chunk.len());
                debug_assert_eq!(gc.cols, rank);
                for (r, &i) in chunk.iter().enumerate() {
                    g.row_mut(i).copy_from_slice(gc.row(r));
                }
            }
            Ok(g)
        })?;

        Ok(LowRankFactor {
            g,
            landmarks: lm,
            landmark_sq: lm_sq,
            whiten,
            rank,
            eigenvalues: eig.values,
            kernel,
            landmark_idx,
        })
    }

    /// Map *new* data (e.g. a test set) into the same feature space:
    /// `G_new = K(X_new, L) W`. Used at prediction time and for CV folds.
    pub fn transform(
        &self,
        x: &SparseMatrix,
        backend: &dyn Stage1Backend,
        chunk: usize,
    ) -> anyhow::Result<Mat> {
        let mut g = Mat::zeros(x.rows, self.rank);
        let rows_all: Vec<usize> = (0..x.rows).collect();
        for c in rows_all.chunks(chunk.max(1)) {
            let gc = backend.g_chunk(
                x,
                c,
                &self.landmarks,
                &self.landmark_sq,
                &self.whiten,
                &self.kernel,
            )?;
            for (r, &i) in c.iter().enumerate() {
                g.row_mut(i).copy_from_slice(gc.row(r));
            }
        }
        Ok(g)
    }

    /// Nyström kernel approximation `k̃(i, j) = ⟨G_i, G_j⟩` (test helper /
    /// diagnostics).
    pub fn approx_kernel(&self, i: usize, j: usize) -> f32 {
        crate::linalg::dense::dot(self.g.row(i), self.g.row(j))
    }

    /// RAM held by `G` in bytes — the paper's "more RAM" budget check.
    pub fn g_bytes(&self) -> usize {
        self.g.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{FeatureStyle, SynthSpec};

    fn dataset(n: usize, p: usize, seed: u64) -> SparseMatrix {
        SynthSpec {
            name: "t".into(),
            n,
            p,
            n_classes: 2,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed,
        }
        .generate()
        .x
    }

    fn compute(x: &SparseMatrix, budget: usize) -> LowRankFactor {
        let cfg = Stage1Config {
            budget,
            chunk: 19, // deliberately not dividing n evenly
            ..Default::default()
        };
        let mut clock = StageClock::new();
        LowRankFactor::compute(x, Kernel::gaussian(0.2), &cfg, &NativeBackend::default(), &mut clock)
            .unwrap()
    }

    #[test]
    fn g_has_expected_shape() {
        let x = dataset(100, 10, 1);
        let f = compute(&x, 32);
        assert_eq!(f.g.rows, 100);
        assert_eq!(f.g.cols, f.rank);
        assert!(f.rank <= 32);
        assert!(f.rank >= 1);
    }

    #[test]
    fn nystrom_exact_on_landmarks() {
        // For landmark points themselves, G G^T reproduces the kernel
        // exactly (up to truncation): Nyström is exact on its inducing set.
        let x = dataset(60, 8, 2);
        let f = compute(&x, 60); // budget = n → full Nyström = exact kernel
        for &i in f.landmark_idx.iter().take(10) {
            for &j in f.landmark_idx.iter().take(10) {
                let exact = f.kernel.eval_sparse(&x, i, &x, j);
                let approx = f.approx_kernel(i, j);
                assert!(
                    (exact - approx).abs() < 1e-3,
                    "({i},{j}): {exact} vs {approx}"
                );
            }
        }
    }

    #[test]
    fn approximation_improves_with_budget() {
        let x = dataset(150, 10, 3);
        let err = |budget: usize| -> f64 {
            let f = compute(&x, budget);
            let mut total = 0.0f64;
            let mut cnt = 0;
            for i in (0..150).step_by(7) {
                for j in (0..150).step_by(11) {
                    let exact = f.kernel.eval_sparse(&x, i, &x, j) as f64;
                    total += (exact - f.approx_kernel(i, j) as f64).abs();
                    cnt += 1;
                }
            }
            total / cnt as f64
        };
        let e_small = err(8);
        let e_big = err(96);
        assert!(
            e_big < e_small * 0.8,
            "budget 96 err {e_big} not clearly below budget 8 err {e_small}"
        );
    }

    #[test]
    fn transform_consistent_with_training_g() {
        // Transforming the training data again must reproduce G.
        let x = dataset(80, 6, 4);
        let f = compute(&x, 24);
        let g2 = f.transform(&x, &NativeBackend::default(), 23).unwrap();
        assert!(f.g.max_abs_diff(&g2) < 1e-5);
    }

    #[test]
    fn rank_truncation_drops_noise_dims() {
        // Low-dimensional data (latent rank ~p) with a large budget: K_BB is
        // strongly rank-deficient under a near-linear kernel scale, so the
        // effective rank must come out well below B.
        let x = dataset(120, 4, 5);
        let cfg = Stage1Config {
            budget: 64,
            eps_rank: 1e-4,
            chunk: 64,
            ..Default::default()
        };
        let mut clock = StageClock::new();
        let f = LowRankFactor::compute(
            &x,
            Kernel::gaussian(0.001), // nearly linear regime
            &cfg,
            &NativeBackend::default(),
            &mut clock,
        )
        .unwrap();
        assert!(f.rank < 64, "rank {} should be < budget", f.rank);
    }

    #[test]
    fn stage_clock_populated() {
        let x = dataset(50, 5, 6);
        let cfg = Stage1Config {
            budget: 16,
            ..Default::default()
        };
        let mut clock = StageClock::new();
        LowRankFactor::compute(&x, Kernel::gaussian(0.3), &cfg, &NativeBackend::default(), &mut clock)
            .unwrap();
        assert!(clock.secs("preparation") > 0.0);
        assert!(clock.secs("matrix_g") > 0.0);
    }

    #[test]
    fn parallel_stage1_bitwise_matches_serial() {
        let x = dataset(90, 8, 8);
        let run = |threads: usize| {
            let cfg = Stage1Config {
                budget: 24,
                chunk: 17,
                threads,
                ..Default::default()
            };
            let mut clock = StageClock::new();
            LowRankFactor::compute(
                &x,
                Kernel::gaussian(0.25),
                &cfg,
                &NativeBackend::with_threads(threads),
                &mut clock,
            )
            .unwrap()
        };
        let serial = run(1);
        for t in [2usize, 3, 8] {
            let par = run(t);
            assert_eq!(serial.g, par.g, "G differs at t={t}");
            assert_eq!(serial.whiten, par.whiten, "whiten differs at t={t}");
            assert_eq!(serial.rank, par.rank, "rank differs at t={t}");
        }
    }

    #[test]
    fn g_bytes_reports_ram() {
        let x = dataset(64, 5, 7);
        let f = compute(&x, 16);
        assert_eq!(f.g_bytes(), 64 * f.rank * 4);
    }
}
