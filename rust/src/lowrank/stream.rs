//! Stage 1 over a streaming [`DataSource`]: the factor without `G`.
//!
//! The classic [`LowRankFactor`] embodies the paper's "more RAM" move —
//! precompute all of `G = K_nB·W` (n × rank, f32) and keep it resident.
//! Out of core that is exactly the matrix we must *not* materialize, so
//! the streaming factor keeps only the O(B·p + B·rank) pieces: the dense
//! landmark matrix, its squared norms, and the whitening map. Consumers
//! (the blockwise solver, streaming evaluation) recompute `G` rows per
//! stripe through the same [`Stage1Backend::g_chunk`] the classic path
//! uses — same inputs per stripe regardless of block budget, which is
//! what carries the bit-identity contract through this layer.
//!
//! Landmark selection draws the same uniform sample as
//! [`crate::lowrank::landmarks::select`] (same RNG seeding), so for the
//! uniform strategy the streaming factor is bitwise the classic factor
//! minus `G`. Landmark *features* are gathered in one masked streaming
//! pass: shards holding no landmark rows are never opened.

use crate::data::block::DataSource;
use crate::data::sparse::SparseMatrix;
use crate::kernel::Kernel;
use crate::linalg::eigen::sym_eig_threads;
use crate::linalg::Mat;
use crate::lowrank::factor::{LowRankFactor, Stage1Backend, Stage1Config};
use crate::lowrank::landmarks::LandmarkStrategy;
use crate::util::rng::Rng;
use crate::util::timer::StageClock;

/// Stage-1 output for the out-of-core path: everything prediction and
/// blockwise training need, except the resident `G`.
#[derive(Clone, Debug)]
pub struct StreamFactor {
    /// Dense landmark matrix (B × p) and its squared row norms.
    pub landmarks: Mat,
    pub landmark_sq: Vec<f32>,
    /// Whitening map `W = V_r Λ_r^{-1/2}` (B × rank).
    pub whiten: Mat,
    /// Effective rank after eigenvalue truncation.
    pub rank: usize,
    /// Eigenvalues of `K_BB` (descending, full length B).
    pub eigenvalues: Vec<f64>,
    pub kernel: Kernel,
    /// Global row ids of the landmarks in the source.
    pub landmark_idx: Vec<usize>,
}

impl StreamFactor {
    /// Run streaming stage 1: sample landmarks, gather their features in
    /// one masked pass under `budget_bytes`, factor `K_BB`. Timing lands
    /// in `clock` under "preparation" like the classic path (there is no
    /// "matrix_g" stage — `G` is never assembled).
    pub fn compute(
        source: &dyn DataSource,
        kernel: Kernel,
        cfg: &Stage1Config,
        budget_bytes: usize,
        clock: &mut StageClock,
    ) -> anyhow::Result<StreamFactor> {
        let n = source.n_rows();
        anyhow::ensure!(n > 0, "empty dataset");
        anyhow::ensure!(
            cfg.strategy == LandmarkStrategy::Uniform,
            "streaming stage 1 supports uniform landmark selection only \
             (k-means++ needs resident features)"
        );
        let threads = cfg.effective_threads();
        clock.time("preparation", || -> anyhow::Result<StreamFactor> {
            // Identical draw to `landmarks::select(Uniform)`: same seed,
            // same first RNG call, sorted — so landmark ids match the
            // classic in-memory factor bit for bit.
            let mut rng = Rng::new(cfg.seed);
            let b = cfg.budget.min(n);
            let mut idx = rng.sample_indices(n, b);
            idx.sort_unstable();

            let mut wanted = vec![false; n];
            for &i in &idx {
                wanted[i] = true;
            }
            let mut lm = Mat::zeros(b, source.n_cols());
            source.for_each_block(budget_bytes, Some(&wanted), &mut |blk| {
                for (k, &g) in blk.rows.iter().enumerate() {
                    let pos = idx
                        .binary_search(&g)
                        .map_err(|_| anyhow::anyhow!("source delivered unrequested row {g}"))?;
                    let (c, v) = blk.x.row(blk.local[k]);
                    let row = lm.row_mut(pos);
                    for (&ci, &vi) in c.iter().zip(v) {
                        row[ci as usize] = vi;
                    }
                }
                Ok(())
            })?;
            let lm_sq = lm.row_sq_norms();
            let k_bb = kernel.symmetric_matrix_threads(&lm, &lm_sq, threads);
            let eig = sym_eig_threads(&k_bb, 40, 1e-12, threads);
            let whiten = eig.whitening_map(eig.effective_rank(cfg.eps_rank));
            let rank = whiten.cols;
            Ok(StreamFactor {
                landmarks: lm,
                landmark_sq: lm_sq,
                whiten,
                rank,
                eigenvalues: eig.values,
                kernel,
                landmark_idx: idx,
            })
        })
    }

    /// `G` rows for a set of block-local rows, through the same backend
    /// entry point the classic assembly uses. Callers pass exactly one
    /// stripe's rows so the computation is block-budget-independent.
    pub fn g_rows(
        &self,
        backend: &dyn Stage1Backend,
        x: &SparseMatrix,
        rows: &[usize],
    ) -> anyhow::Result<Mat> {
        backend.g_chunk(x, rows, &self.landmarks, &self.landmark_sq, &self.whiten, &self.kernel)
    }

    /// Package as a [`LowRankFactor`] for the model container. `g` is
    /// empty — the same shape [`crate::model::io::load`] reconstructs, so
    /// a streamed model serializes identically to a classic one.
    pub fn to_model_factor(&self) -> LowRankFactor {
        LowRankFactor {
            g: Mat::zeros(0, self.rank),
            landmarks: self.landmarks.clone(),
            landmark_sq: self.landmark_sq.clone(),
            whiten: self.whiten.clone(),
            rank: self.rank,
            eigenvalues: self.eigenvalues.clone(),
            kernel: self.kernel,
            landmark_idx: self.landmark_idx.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::block::MemorySource;
    use crate::data::synth::{FeatureStyle, SynthSpec};
    use crate::data::Dataset;
    use crate::lowrank::factor::NativeBackend;

    fn dataset(n: usize, seed: u64) -> Dataset {
        SynthSpec {
            name: "t".into(),
            n,
            p: 10,
            n_classes: 2,
            sep: 2.0,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed,
        }
        .generate()
    }

    #[test]
    fn stream_factor_matches_classic_minus_g() {
        let ds = dataset(300, 11);
        let cfg = Stage1Config { budget: 48, ..Default::default() };
        let kernel = Kernel::gaussian(0.2);
        let mut clock = StageClock::new();
        let classic =
            LowRankFactor::compute(&ds.x, kernel, &cfg, &NativeBackend::default(), &mut clock)
                .unwrap();
        let src = MemorySource::new(&ds);
        for budget in [0usize, 2_000] {
            let mut clock2 = StageClock::new();
            let sf = StreamFactor::compute(&src, kernel, &cfg, budget, &mut clock2).unwrap();
            assert_eq!(sf.landmark_idx, classic.landmark_idx, "budget {budget}");
            assert_eq!(sf.landmarks.data, classic.landmarks.data);
            assert_eq!(sf.whiten.data, classic.whiten.data);
            assert_eq!(sf.rank, classic.rank);
            assert_eq!(sf.eigenvalues, classic.eigenvalues);
            assert!(clock2.secs("preparation") > 0.0);
        }
    }

    #[test]
    fn g_rows_matches_classic_g() {
        let ds = dataset(200, 12);
        let cfg = Stage1Config { budget: 32, ..Default::default() };
        let kernel = Kernel::gaussian(0.15);
        let mut clock = StageClock::new();
        let classic =
            LowRankFactor::compute(&ds.x, kernel, &cfg, &NativeBackend::default(), &mut clock)
                .unwrap();
        let src = MemorySource::new(&ds);
        let sf = StreamFactor::compute(&src, kernel, &cfg, 0, &mut StageClock::new()).unwrap();
        let rows: Vec<usize> = (40..60).collect();
        let g = sf.g_rows(&NativeBackend::default(), &ds.x, &rows).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            assert_eq!(g.row(r), classic.g.row(i), "row {i}");
        }
    }

    #[test]
    fn model_factor_has_empty_g() {
        let ds = dataset(80, 13);
        let src = MemorySource::new(&ds);
        let cfg = Stage1Config { budget: 16, ..Default::default() };
        let sf =
            StreamFactor::compute(&src, Kernel::gaussian(0.1), &cfg, 0, &mut StageClock::new())
                .unwrap();
        let f = sf.to_model_factor();
        assert_eq!(f.g.rows, 0);
        assert_eq!(f.g.cols, sf.rank);
        assert_eq!(f.rank, sf.rank);
    }
}
