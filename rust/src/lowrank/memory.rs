//! "More RAM!" — memory planning for the complete precomputation of `G`.
//!
//! The paper's headline trade-off (§4): a low-rank factor of size `n × B`
//! replaces the `n × n` kernel matrix, so with `B ≈ 10³..10⁴` the entire
//! factor fits in host RAM (their example: B = 10³, n = 10⁶ fits in an
//! 8 GB laptop; 512 GB servers afford two orders of magnitude more).
//! This module makes that arithmetic a first-class, testable object:
//! estimate the footprint of a training plan, check it against a budget,
//! and — inverting the paper's reasoning — compute the largest affordable
//! budget `B` for a given machine.

use crate::data::dataset::Dataset;

const F32: usize = std::mem::size_of::<f32>();

/// Estimated peak RAM of one LPD-SVM training run (bytes, dominant terms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryPlan {
    /// The `n × B` factor G — the paper's dominant term.
    pub g_bytes: usize,
    /// Landmarks (B × p dense) + K_BB (B × B) + eigenvectors (B × B) +
    /// whitening map (B × B).
    pub stage1_bytes: usize,
    /// Solver state: α, gradients/bookkeeping (n) + v (B) per concurrent
    /// binary problem.
    pub solver_bytes: usize,
    /// Input data (CSR: values + indices + indptr).
    pub data_bytes: usize,
}

impl MemoryPlan {
    /// Build the plan for a dataset / budget / thread count.
    pub fn estimate(data: &Dataset, budget: usize, threads: usize) -> MemoryPlan {
        let n = data.len();
        let b = budget.min(n);
        let p = data.dim();
        MemoryPlan {
            g_bytes: n * b * F32,
            stage1_bytes: b * p * F32 + 3 * b * b * F32,
            solver_bytes: threads.max(1) * (2 * n * F32 + b * F32 + n),
            data_bytes: data.x.nnz() * (F32 + std::mem::size_of::<u32>())
                + (n + 1) * std::mem::size_of::<usize>(),
        }
    }

    /// Total bytes.
    pub fn total(&self) -> usize {
        self.g_bytes + self.stage1_bytes + self.solver_bytes + self.data_bytes
    }

    /// Does the plan fit in `budget_bytes`?
    pub fn fits(&self, budget_bytes: usize) -> bool {
        self.total() <= budget_bytes
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        let gib = |x: usize| x as f64 / (1024.0 * 1024.0 * 1024.0);
        format!(
            "G {:.3} GiB + stage1 {:.3} GiB + solver {:.3} GiB + data {:.3} GiB = {:.3} GiB",
            gib(self.g_bytes),
            gib(self.stage1_bytes),
            gib(self.solver_bytes),
            gib(self.data_bytes),
            gib(self.total())
        )
    }
}

/// Largest budget `B` whose plan fits in `budget_bytes` (0 if even B = 16
/// does not fit). Monotone in B, so binary search.
pub fn max_affordable_budget(data: &Dataset, threads: usize, budget_bytes: usize) -> usize {
    let (mut lo, mut hi) = (0usize, data.len().max(1));
    if !MemoryPlan::estimate(data, 16.min(hi), threads).fits(budget_bytes) {
        return 0;
    }
    while lo < hi {
        let mid = lo + (hi - lo).div_ceil(2);
        if MemoryPlan::estimate(data, mid, threads).fits(budget_bytes) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::SparseMatrix;

    fn dataset(n: usize, p: usize, nnz_per_row: usize) -> Dataset {
        let rows: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|i| {
                (0..nnz_per_row)
                    .map(|k| (((i + k * 7) % p) as u32, 1.0))
                    .collect::<std::collections::BTreeMap<u32, f32>>()
                    .into_iter()
                    .collect()
            })
            .collect();
        let x = SparseMatrix::from_rows(p, &rows);
        let labels = (0..n).map(|i| (i % 2) as u32).collect();
        Dataset::new("m", x, labels, 2)
    }

    #[test]
    fn paper_laptop_example() {
        // Paper §4: B = 10³, n = 10⁶ → G is 4 GB, fits an 8 GB laptop.
        let n = 1_000_000;
        let b = 1_000;
        // Synthetic metadata-only dataset (tiny p to keep the test fast).
        let data = dataset(1_000, 10, 4); // scale G arithmetic by hand:
        let plan = MemoryPlan {
            g_bytes: n * b * F32,
            ..MemoryPlan::estimate(&data, b, 1)
        };
        assert_eq!(plan.g_bytes, 4_000_000_000);
        assert!(plan.fits(8 * 1024 * 1024 * 1024));
    }

    #[test]
    fn g_dominates_for_large_n() {
        let data = dataset(20_000, 50, 8);
        let plan = MemoryPlan::estimate(&data, 1_000, 4);
        assert!(plan.g_bytes > plan.stage1_bytes);
        assert!(plan.g_bytes > plan.solver_bytes);
        assert!(plan.g_bytes > plan.data_bytes);
        assert_eq!(plan.g_bytes, 20_000 * 1_000 * 4);
    }

    #[test]
    fn budget_clamped_to_n() {
        let data = dataset(100, 10, 3);
        let plan = MemoryPlan::estimate(&data, 10_000, 1);
        assert_eq!(plan.g_bytes, 100 * 100 * 4);
    }

    #[test]
    fn max_affordable_is_monotone_and_tight() {
        let data = dataset(5_000, 30, 5);
        let small = max_affordable_budget(&data, 1, 2 * 1024 * 1024);
        let large = max_affordable_budget(&data, 1, 64 * 1024 * 1024);
        assert!(small < large, "{small} !< {large}");
        // The found budget fits; the next one up does not (unless capped).
        assert!(MemoryPlan::estimate(&data, large, 1).fits(64 * 1024 * 1024));
        if large < data.len() {
            assert!(!MemoryPlan::estimate(&data, large + 1, 1).fits(64 * 1024 * 1024));
        }
    }

    #[test]
    fn zero_when_nothing_fits() {
        let data = dataset(10_000, 30, 5);
        assert_eq!(max_affordable_budget(&data, 1, 1024), 0);
    }

    #[test]
    fn summary_mentions_all_terms() {
        let data = dataset(100, 10, 3);
        let s = MemoryPlan::estimate(&data, 32, 1).summary();
        for term in ["G ", "stage1", "solver", "data", "="] {
            assert!(s.contains(term), "missing {term} in {s}");
        }
    }
}
