//! The paper's "simplistic yet robust and effective" shrinking heuristic.
//!
//! Rule (§4 "Shrinking"): if a variable is visited `k` times in a row (we
//! use the paper's k = 5) without changing, remove it from the active set;
//! dedicate a fixed fraction η (paper: 5%) of total computation time to
//! sweeps over the removed variables that re-activate any violator. Unlike
//! LIBSVM's heuristic this has a *systematic* re-activation path, which is
//! what makes it robust.

/// Active-set bookkeeping with unchanged-visit counters.
pub struct ActiveSet {
    /// Local variable indices currently active, iterated each epoch.
    pub active: Vec<u32>,
    /// Consecutive unchanged-visit count per variable (saturating at k).
    unchanged: Vec<u8>,
    /// Threshold k.
    k: u8,
    /// Variables removed from the active set.
    pub inactive: Vec<u32>,
    /// Lifetime count of shrink moves (a variable shrunk twice counts
    /// twice) — telemetry for the solver's trace spans and summary log.
    pub total_shrunk: u64,
    /// Lifetime count of re-activation moves.
    pub total_reactivated: u64,
}

impl ActiveSet {
    pub fn new(n: usize, k: u8) -> Self {
        ActiveSet {
            active: (0..n as u32).collect(),
            unchanged: vec![0; n],
            k,
            inactive: Vec::new(),
            total_shrunk: 0,
            total_reactivated: 0,
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Record the outcome of visiting variable `i`. Returns `true` if the
    /// variable just crossed the threshold and should be shrunk.
    #[inline]
    pub fn visit(&mut self, i: u32, changed: bool) -> bool {
        let c = &mut self.unchanged[i as usize];
        if changed {
            *c = 0;
            false
        } else {
            *c = c.saturating_add(1);
            *c >= self.k
        }
    }

    /// Remove the variables flagged during the last epoch (swap-remove to
    /// stay O(#removed)); their ids move to the inactive list.
    pub fn shrink(&mut self, flagged: &[u32]) {
        if flagged.is_empty() {
            return;
        }
        // Mark and filter in one pass (flagged lists are small).
        let mut mark = vec![false; self.unchanged.len()];
        for &i in flagged {
            mark[i as usize] = true;
        }
        let before = self.active.len();
        self.active.retain(|&i| {
            if mark[i as usize] {
                self.inactive.push(i);
                false
            } else {
                true
            }
        });
        self.total_shrunk += (before - self.active.len()) as u64;
    }

    /// Capture the full shrinking state for a checkpoint:
    /// `(active, unchanged, inactive, total_shrunk, total_reactivated)`.
    /// Order within `active`/`inactive` is part of the state — epoch
    /// iteration order (and hence the bit-exact solve trajectory) depends
    /// on it.
    #[allow(clippy::type_complexity)]
    pub fn snapshot(&self) -> (Vec<u32>, Vec<u8>, Vec<u32>, u64, u64) {
        (
            self.active.clone(),
            self.unchanged.clone(),
            self.inactive.clone(),
            self.total_shrunk,
            self.total_reactivated,
        )
    }

    /// Rebuild an active set from a [`ActiveSet::snapshot`] capture plus
    /// the original threshold `k`.
    pub fn from_snapshot(
        active: Vec<u32>,
        unchanged: Vec<u8>,
        inactive: Vec<u32>,
        total_shrunk: u64,
        total_reactivated: u64,
        k: u8,
    ) -> Self {
        ActiveSet { active, unchanged, k, inactive, total_shrunk, total_reactivated }
    }

    /// Move `i` (currently inactive) back into the active set with a reset
    /// counter.
    pub fn reactivate_all(&mut self, violators: &[u32]) {
        if violators.is_empty() {
            return;
        }
        let mut mark = vec![false; self.unchanged.len()];
        for &i in violators {
            mark[i as usize] = true;
            self.unchanged[i as usize] = 0;
        }
        let before = self.inactive.len();
        self.inactive.retain(|&i| {
            if mark[i as usize] {
                self.active.push(i);
                false
            } else {
                true
            }
        });
        self.total_reactivated += (before - self.inactive.len()) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrinks_after_k_unchanged_visits() {
        let mut s = ActiveSet::new(3, 5);
        for _ in 0..4 {
            assert!(!s.visit(1, false));
        }
        assert!(s.visit(1, false), "5th unchanged visit should flag");
    }

    #[test]
    fn change_resets_counter() {
        let mut s = ActiveSet::new(2, 5);
        for _ in 0..4 {
            s.visit(0, false);
        }
        s.visit(0, true); // reset
        for _ in 0..4 {
            assert!(!s.visit(0, false));
        }
        assert!(s.visit(0, false));
    }

    #[test]
    fn shrink_moves_to_inactive() {
        let mut s = ActiveSet::new(5, 5);
        s.shrink(&[1, 3]);
        assert_eq!(s.n_active(), 3);
        assert_eq!(s.inactive, vec![1, 3]);
        assert_eq!(s.total_shrunk, 2);
        assert!(!s.active.contains(&1));
        assert!(!s.active.contains(&3));
    }

    #[test]
    fn reactivate_returns_violators() {
        let mut s = ActiveSet::new(5, 5);
        s.shrink(&[0, 2, 4]);
        s.reactivate_all(&[2, 4]);
        assert_eq!(s.inactive, vec![0]);
        assert_eq!(s.n_active(), 4);
        assert_eq!(s.total_shrunk, 3);
        assert_eq!(s.total_reactivated, 2);
        assert!(s.active.contains(&2));
        // counters were reset
        for _ in 0..4 {
            assert!(!s.visit(2, false));
        }
        assert!(s.visit(2, false));
    }

    #[test]
    fn empty_ops_are_noops() {
        let mut s = ActiveSet::new(3, 5);
        s.shrink(&[]);
        s.reactivate_all(&[]);
        assert_eq!(s.n_active(), 3);
        assert!(s.inactive.is_empty());
    }
}
