//! The dual coordinate-ascent loop — the solver's hot path.
//!
//! Per-step cost is `O(B)`: one dot product (gradient) and one axpy
//! (update of the maintained primal vector `v`). The paper reports several
//! million steps per second per core at B = 10³; `benches/hot_loop.rs`
//! tracks that number for this implementation.

use crate::linalg::dense::{axpy, dot};
use crate::solver::shrinking::ActiveSet;
use crate::solver::state::{DualState, ProblemView};
use crate::util::rng::Rng;
use std::time::Instant;

/// Options for one linear-SVM training run.
#[derive(Clone, Debug)]
pub struct SolverOptions {
    /// Box constraint `C = 1/(λn)`.
    pub c: f64,
    /// Stopping tolerance on the maximum KKT violation (LIBLINEAR-style).
    pub eps: f64,
    /// Hard cap on epochs (each epoch visits every active variable once).
    pub max_epochs: usize,
    /// Enable the paper's shrinking heuristic.
    pub shrinking: bool,
    /// Shrink after this many consecutive unchanged visits (paper: 5).
    pub shrink_k: u8,
    /// Fraction of compute time spent re-checking shrunk variables
    /// (paper: 0.05).
    pub reactivate_frac: f64,
    /// RNG seed for the per-epoch permutation.
    pub seed: u64,
    /// Warm-start dual variables (length = problem size); clipped to
    /// `[0, C]`. `None` = cold start.
    pub warm_alpha: Option<Vec<f32>>,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions {
            c: 1.0,
            eps: 1e-2,
            max_epochs: 1000,
            shrinking: true,
            shrink_k: 5,
            reactivate_frac: 0.05,
            seed: 0xCD,
            warm_alpha: None,
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Dual variables (aligned with the problem's local indices).
    pub alpha: Vec<f32>,
    /// Primal weight vector in G-space: `w = Σ αᵢ yᵢ Gᵢ` (length = rank).
    /// Prediction on new data is simply `score = G_new · w`.
    pub w: Vec<f32>,
    /// Final dual objective.
    pub objective: f64,
    /// Total coordinate steps performed.
    pub steps: u64,
    pub epochs: usize,
    pub sv_count: usize,
    /// Whether the KKT criterion was met (vs epoch cap).
    pub converged: bool,
    /// Final maximum KKT violation over all variables.
    pub violation: f64,
    pub train_secs: f64,
    /// Active variables remaining at termination (after shrinking).
    pub final_active: usize,
}

/// Hint the prefetcher at the start of row `i` (the hardware streamer
/// follows once the first lines arrive). No-op on non-x86_64.
#[inline]
fn prefetch_row(problem: &ProblemView, i: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        let row = problem.feature_row(i);
        let ptr = row.as_ptr() as *const i8;
        // SAFETY: `_mm_prefetch` is a pure cache hint — it cannot fault
        // even on an unmapped address — and `ptr` is a valid slice base;
        // the +64/+128 offsets are gated on the row length below.
        unsafe {
            use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            // First three cache lines only: enough to hide the row-start
            // latency while the hardware streamer follows the rest. A
            // full-row prefetch sweep measured ~15% SLOWER (it saturates
            // the load ports) — see EXPERIMENTS.md §Perf iteration 2.
            // Depth tuned empirically: 3 lines ≻ 1 line ≻ 6 lines ≻ full
            // row (§Perf iterations 2/4).
            _mm_prefetch(ptr, _MM_HINT_T0);
            if row.len() >= 32 {
                _mm_prefetch(ptr.add(64), _MM_HINT_T0);
                _mm_prefetch(ptr.add(128), _MM_HINT_T0);
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (problem, i);
    }
}

/// Projected-gradient violation of variable `i` (LIBLINEAR eq. for the
/// box-constrained dual): 0 when the KKT conditions hold at `α_i`.
/// Shared with the blockwise solver ([`crate::solver::block`]) so both
/// paths apply the identical KKT test.
#[inline]
pub(crate) fn violation(grad: f32, alpha: f32, c: f32) -> f32 {
    if alpha <= 0.0 {
        (-grad).max(0.0) // gradient ascent direction blocked at 0? grad<0 ok
    } else if alpha >= c {
        grad.max(0.0)
    } else {
        grad.abs()
    }
}

/// Everything the CD loop carries from one epoch to the next, captured at
/// an epoch boundary. Restoring a snapshot and continuing produces the
/// *bit-identical* trajectory of the uninterrupted run: the per-epoch
/// permutation comes from the restored RNG state, the shrinking set and
/// its unchanged-visit counters are restored in iteration order, and the
/// η-fraction re-activation budget resumes from the restored work
/// counters. Everything else the loop touches (`order`, `flagged`, the
/// diagonal) is rebuilt deterministically at the top of each epoch.
#[derive(Clone, Debug)]
pub struct SolverSnapshot {
    /// Epochs completed when the snapshot was taken.
    pub epochs: usize,
    /// Coordinate steps performed so far.
    pub steps: u64,
    /// Dual variables.
    pub alpha: Vec<f32>,
    /// Maintained primal vector `v = Σ αᵢ yᵢ Gᵢ`.
    pub v: Vec<f32>,
    /// Active variable ids, in iteration order.
    pub active: Vec<u32>,
    /// Consecutive unchanged-visit counters (all variables).
    pub unchanged: Vec<u8>,
    /// Shrunk variable ids, in re-activation scan order.
    pub inactive: Vec<u32>,
    pub total_shrunk: u64,
    pub total_reactivated: u64,
    /// xoshiro256++ state of the permutation RNG.
    pub rng: [u64; 4],
    /// Work counters for the η-fraction re-activation rule.
    pub active_work: u64,
    pub check_work: u64,
}

/// Train a linear SVM on the problem view. See module docs for the update
/// rule; this function adds the paper's shrinking/stopping/warm-start
/// machinery around the O(B) hot step.
pub fn solve(problem: &ProblemView, opts: &SolverOptions) -> Solution {
    solve_resumable(problem, opts, None, 0, |_| {})
}

/// [`solve`] with crash-safe checkpointing hooks.
///
/// `resume` restarts the loop from a previously captured
/// [`SolverSnapshot`] (it overrides `opts.warm_alpha`). When
/// `checkpoint_every > 0`, `sink` is called with a fresh snapshot after
/// every `checkpoint_every`-th completed epoch that does not terminate
/// the solve — persisting it is the caller's business
/// ([`crate::coordinator::checkpoint`]).
pub fn solve_resumable(
    problem: &ProblemView,
    opts: &SolverOptions,
    resume: Option<SolverSnapshot>,
    checkpoint_every: usize,
    mut sink: impl FnMut(&SolverSnapshot),
) -> Solution {
    let n = problem.len();
    // Validate the warm start up front: a mismatched α used to fail deep
    // inside `DualState` with a bare length assert, long after the caller
    // context (which pair, which fold) was gone.
    if let Some(a) = &opts.warm_alpha {
        assert!(
            a.len() == n,
            "SolverOptions::warm_alpha has {} entries but the problem has {} \
             variables — warm starts must be aligned with the problem's local \
             indices (same subset, same order)",
            a.len(),
            n
        );
    }
    let c = opts.c as f32;
    // lint: allow(determinism-domain) — feeds only the train_secs stat
    let t_start = Instant::now();

    let mut state = match &opts.warm_alpha {
        Some(a) => DualState::warm(problem, a.clone(), c),
        None => DualState::zeros(n, problem.dim()),
    };
    if n == 0 {
        return finish(problem, state, 0, 0, true, 0.0, t_start, 0);
    }

    let diag = problem.diag();
    let mut rng = Rng::new(opts.seed);
    let mut active = ActiveSet::new(n, opts.shrink_k);
    let mut flagged: Vec<u32> = Vec::new();
    let mut order: Vec<u32> = (0..n as u32).collect();

    let mut steps: u64 = 0;
    let mut epochs = 0usize;
    let mut converged = false;
    let mut final_violation = f64::MAX;
    // Work accounting for the η-fraction re-activation rule. The paper
    // phrases the budget in wall-clock time; we count coordinate visits
    // instead (each visit is O(B), so the ratio is the same) — this keeps
    // the solver fully deterministic for a given seed.
    let mut active_work: u64 = 0;
    let mut check_work: u64 = 0;

    if let Some(snap) = resume {
        assert!(
            snap.alpha.len() == n && snap.unchanged.len() == n,
            "SolverSnapshot has {} variables but the problem has {n} — a \
             checkpoint only resumes the exact problem it was taken from",
            snap.alpha.len()
        );
        assert!(
            snap.v.len() == problem.dim(),
            "SolverSnapshot v has dim {} but the problem has dim {}",
            snap.v.len(),
            problem.dim()
        );
        state = DualState { alpha: snap.alpha, v: snap.v };
        active = ActiveSet::from_snapshot(
            snap.active,
            snap.unchanged,
            snap.inactive,
            snap.total_shrunk,
            snap.total_reactivated,
            opts.shrink_k,
        );
        rng = Rng::from_state(snap.rng);
        steps = snap.steps;
        epochs = snap.epochs;
        active_work = snap.active_work;
        check_work = snap.check_work;
    }
    // Epoch wall-time distribution (µs) for the solve summary — same
    // log₂ histogram the serve metrics use. One Instant pair per epoch;
    // noise against the O(n·B) epoch body.
    let epoch_us = crate::obs::Histogram::new();
    let mut solve_span = crate::obs::Span::new("solve");
    solve_span.arg("n", n as f64);

    while epochs < opts.max_epochs {
        epochs += 1;
        // lint: allow(determinism-domain) — epoch-time histogram only
        let epoch_start = Instant::now();
        let mut epoch_span = crate::obs::Span::new("solve.epoch");
        let mut epoch_reactivated: u64 = 0;

        // Random permutation of the active set (round-robin in randomized
        // order, as the paper prescribes).
        order.clear();
        order.extend_from_slice(&active.active);
        rng.shuffle(&mut order);

        let mut max_viol = 0.0f32;
        flagged.clear();
        for (k, &i) in order.iter().enumerate() {
            let iu = i as usize;
            // Perf: the permutation makes row access pattern-free for the
            // hardware prefetcher, so kick off the next row's fetch now —
            // it overlaps with this step's dot+axpy (§Perf, +~10% at
            // B ≥ 512).
            if !cfg!(feature = "no-prefetch") {
                if let Some(&next) = order.get(k + 1) {
                    prefetch_row(problem, next as usize);
                }
            }
            let gi = problem.feature_row(iu);
            let yi = problem.y[iu];
            // grad of -D w.r.t. α_i: y_i <G_i, v> − 1.
            let grad = yi * dot(gi, &state.v) - 1.0;
            let a_old = state.alpha[iu];
            let viol = violation(grad, a_old, c);
            if viol > max_viol {
                max_viol = viol;
            }
            let d = diag[iu];
            let mut changed = false;
            if viol > 1e-12 && d > 0.0 {
                let a_new = (a_old - grad / d).clamp(0.0, c);
                let delta = a_new - a_old;
                if delta != 0.0 {
                    state.alpha[iu] = a_new;
                    axpy(delta * yi, gi, &mut state.v);
                    changed = true;
                }
            }
            steps += 1;
            if opts.shrinking && active.visit(i, changed) {
                flagged.push(i);
            }
        }
        if opts.shrinking {
            active.shrink(&flagged);
        }
        active_work += order.len() as u64;

        let active_converged = (max_viol as f64) < opts.eps;
        epoch_span.arg("epoch", epochs as f64);
        epoch_span.arg("kkt", max_viol as f64);
        epoch_span.arg("active", active.n_active() as f64);
        epoch_span.arg("shrunk", flagged.len() as f64);

        // Re-activation sweep: either the η work budget says we owe one, or
        // the active set has (apparently) converged and we must verify the
        // full problem before declaring victory.
        let owe_check = opts.shrinking
            && !active.inactive.is_empty()
            && (check_work as f64)
                < opts.reactivate_frac * (active_work + check_work) as f64;
        if owe_check || active_converged {
            let mut violators: Vec<u32> = Vec::new();
            let mut max_inactive_viol = 0.0f32;
            check_work += active.inactive.len() as u64;
            for &i in &active.inactive {
                let iu = i as usize;
                let grad = problem.y[iu] * dot(problem.feature_row(iu), &state.v) - 1.0;
                let viol = violation(grad, state.alpha[iu], c);
                if viol > max_inactive_viol {
                    max_inactive_viol = viol;
                }
                if (viol as f64) >= opts.eps {
                    violators.push(i);
                }
            }
            epoch_reactivated += violators.len() as u64;
            active.reactivate_all(&violators);

            if active_converged {
                if violators.is_empty() {
                    final_violation = max_viol.max(max_inactive_viol) as f64;
                    converged = true;
                    epoch_us.record(epoch_start.elapsed().as_micros() as u64);
                    break;
                }
                // Violators were re-activated: the next epoch will move
                // them, so the violation measured just now is stale the
                // moment we continue. Reset it so a later `max_epochs`
                // exit recomputes over the final iterate instead of
                // reporting this epoch's value (which could even sit
                // below `eps` while `converged` stays false).
                final_violation = f64::MAX;
            }
        }
        if active.n_active() == 0 {
            // Everything shrunk; force a verification sweep next epoch by
            // reactivating everything still violating. If none violates we
            // are done.
            let mut violators: Vec<u32> = Vec::new();
            let mut mv = 0.0f32;
            for &i in &active.inactive {
                let iu = i as usize;
                let grad = problem.y[iu] * dot(problem.feature_row(iu), &state.v) - 1.0;
                let viol = violation(grad, state.alpha[iu], c);
                mv = mv.max(viol);
                if (viol as f64) >= opts.eps {
                    violators.push(i);
                }
            }
            epoch_reactivated += violators.len() as u64;
            active.reactivate_all(&violators);
            if active.n_active() == 0 {
                final_violation = mv as f64;
                converged = true;
                epoch_us.record(epoch_start.elapsed().as_micros() as u64);
                break;
            }
        }
        epoch_span.arg("reactivated", epoch_reactivated as f64);
        drop(epoch_span);
        epoch_us.record(epoch_start.elapsed().as_micros() as u64);

        // Checkpoint boundary: every surviving epoch multiple of the
        // interval. The convergence paths above `break` before reaching
        // here, so a snapshot is only taken when the loop will continue —
        // restoring it replays the remaining epochs bit-identically.
        if checkpoint_every > 0 && epochs % checkpoint_every == 0 && epochs < opts.max_epochs {
            let (a, u, i, ts, tr) = active.snapshot();
            sink(&SolverSnapshot {
                epochs,
                steps,
                alpha: state.alpha.clone(),
                v: state.v.clone(),
                active: a,
                unchanged: u,
                inactive: i,
                total_shrunk: ts,
                total_reactivated: tr,
                rng: rng.state(),
                active_work,
                check_work,
            });
        }
    }

    if final_violation == f64::MAX {
        // Terminated on the epoch cap — compute the true violation once.
        let mut mv = 0.0f32;
        for i in 0..n {
            let grad = problem.y[i] * dot(problem.feature_row(i), &state.v) - 1.0;
            mv = mv.max(violation(grad, state.alpha[i], c));
        }
        final_violation = mv as f64;
        converged = final_violation < opts.eps;
    }

    solve_span.arg("epochs", epochs as f64);
    solve_span.arg("steps", steps as f64);
    solve_span.arg("converged", converged as u8 as f64);
    solve_span.arg("kkt", final_violation);
    solve_span.arg("epoch_p50_us", epoch_us.quantile(0.50) as f64);
    solve_span.arg("epoch_p99_us", epoch_us.quantile(0.99) as f64);
    crate::log_debug!(
        "solver",
        "n={n} epochs={epochs} steps={steps} converged={converged} kkt={final_violation:.3e} \
         shrunk={} reactivated={} epoch_p50_us={} epoch_p99_us={}",
        active.total_shrunk,
        active.total_reactivated,
        epoch_us.quantile(0.50),
        epoch_us.quantile(0.99)
    );

    let final_active = active.n_active();
    finish(
        problem,
        state,
        steps,
        epochs,
        converged,
        final_violation,
        t_start,
        final_active,
    )
}

#[allow(clippy::too_many_arguments)]
fn finish(
    _problem: &ProblemView,
    state: DualState,
    steps: u64,
    epochs: usize,
    converged: bool,
    violation: f64,
    t_start: Instant,
    final_active: usize,
) -> Solution {
    Solution {
        objective: state.objective(),
        sv_count: state.sv_count(),
        w: state.v,
        alpha: state.alpha,
        steps,
        epochs,
        converged,
        violation,
        train_secs: t_start.elapsed().as_secs_f64(),
        final_active,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    /// Separable 2-cluster problem in 2-D feature space.
    fn separable(n: usize, seed: u64) -> (Mat, Vec<usize>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut g = Mat::zeros(n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            g.set(i, 0, cls * 2.0 + rng.normal() as f32 * 0.3);
            g.set(i, 1, rng.normal() as f32 * 0.3);
            y.push(cls);
        }
        let rows = (0..n).collect();
        (g, rows, y)
    }

    #[test]
    fn solves_separable_problem() {
        let (g, rows, y) = separable(200, 1);
        let p = ProblemView::new(&g, &rows, &y);
        let sol = solve(&p, &SolverOptions::default());
        assert!(sol.converged, "violation {}", sol.violation);
        // Perfect classification on train data.
        for i in 0..200 {
            let score = dot(p.feature_row(i), &sol.w);
            assert!(score * y[i] > 0.0, "misclassified train point {i}");
        }
    }

    #[test]
    fn alpha_stays_in_box() {
        let (g, rows, y) = separable(100, 2);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            c: 0.37,
            ..Default::default()
        };
        let sol = solve(&p, &opts);
        for &a in &sol.alpha {
            assert!((0.0..=0.37 + 1e-6).contains(&a), "alpha {a} outside box");
        }
    }

    #[test]
    fn kkt_violation_below_eps_at_convergence() {
        let (g, rows, y) = separable(150, 3);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            eps: 1e-3,
            ..Default::default()
        };
        let sol = solve(&p, &opts);
        assert!(sol.converged);
        assert!(sol.violation < 1e-3, "violation {}", sol.violation);
        // Independently verify KKT over all variables.
        for i in 0..p.len() {
            let grad = y[i] * dot(p.feature_row(i), &sol.w) - 1.0;
            let viol = super::violation(grad, sol.alpha[i], opts.c as f32);
            assert!(viol < 1e-3 + 1e-6, "var {i} violation {viol}");
        }
    }

    #[test]
    fn shrinking_matches_no_shrinking_objective() {
        let (g, rows, y) = separable(300, 4);
        let p = ProblemView::new(&g, &rows, &y);
        let base = SolverOptions {
            eps: 1e-4,
            ..Default::default()
        };
        let with = solve(&p, &base);
        let without = solve(
            &p,
            &SolverOptions {
                shrinking: false,
                ..base
            },
        );
        assert!(
            (with.objective - without.objective).abs()
                < 1e-3 * (1.0 + without.objective.abs()),
            "{} vs {}",
            with.objective,
            without.objective
        );
    }

    #[test]
    fn warm_start_reaches_same_solution() {
        let (g, rows, y) = separable(200, 5);
        let p = ProblemView::new(&g, &rows, &y);
        let opts_small_c = SolverOptions {
            c: 0.5,
            eps: 1e-4,
            ..Default::default()
        };
        let sol_small = solve(&p, &opts_small_c);
        let cold = solve(
            &p,
            &SolverOptions {
                c: 1.0,
                eps: 1e-4,
                ..Default::default()
            },
        );
        let warm = solve(
            &p,
            &SolverOptions {
                c: 1.0,
                eps: 1e-4,
                warm_alpha: Some(sol_small.alpha.clone()),
                ..Default::default()
            },
        );
        assert!(
            (warm.objective - cold.objective).abs() < 1e-3 * (1.0 + cold.objective.abs()),
            "warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
        // Warm start should take no more epochs than cold start.
        assert!(warm.epochs <= cold.epochs, "{} > {}", warm.epochs, cold.epochs);
    }

    #[test]
    #[should_panic(expected = "warm_alpha has 3 entries but the problem has 100")]
    fn mismatched_warm_start_fails_fast_with_context() {
        // Regression: this used to fail deep inside DualState with a bare
        // "warm-start size mismatch", losing which solve was at fault.
        let (g, rows, y) = separable(100, 9);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            warm_alpha: Some(vec![0.1, 0.2, 0.3]),
            ..Default::default()
        };
        let _ = solve(&p, &opts);
    }

    #[test]
    fn violation_is_fresh_when_terminating_on_epoch_cap() {
        // Regression: when an epoch passed the active-set convergence
        // check but the re-activation sweep found violators,
        // `final_violation` kept that epoch's value; terminating on
        // `max_epochs` then skipped the fresh recomputation and reported
        // a stale violation (possibly < eps with converged == false).
        // Sweep tiny epoch caps on a noisy problem with aggressive
        // shrinking and frequent re-activation sweeps to force the path.
        let (g, rows, mut y) = separable(250, 21);
        let mut rng = Rng::new(77);
        for yi in y.iter_mut() {
            if rng.bool(0.25) {
                *yi = -*yi;
            }
        }
        let p = ProblemView::new(&g, &rows, &y);
        for max_epochs in 1..=12 {
            let opts = SolverOptions {
                c: 4.0,
                eps: 0.05,
                max_epochs,
                shrink_k: 1,
                reactivate_frac: 0.9,
                ..Default::default()
            };
            let sol = solve(&p, &opts);
            // The stale-value symptom: a sub-eps violation reported on a
            // run that claims it did NOT converge.
            assert!(
                sol.converged || sol.violation >= opts.eps,
                "max_epochs={max_epochs}: converged=false but violation {} < eps {}",
                sol.violation,
                opts.eps
            );
            if !sol.converged {
                // Epoch-cap exits must report the violation of the final
                // iterate — identical to an independent recomputation.
                let mut true_viol = 0.0f32;
                for i in 0..p.len() {
                    let grad = y[i] * dot(p.feature_row(i), &sol.w) - 1.0;
                    true_viol = true_viol.max(super::violation(grad, sol.alpha[i], opts.c as f32));
                }
                assert!(
                    (sol.violation - true_viol as f64).abs() <= 1e-6 * (1.0 + true_viol as f64),
                    "max_epochs={max_epochs}: reported {} vs recomputed {true_viol}",
                    sol.violation
                );
            }
        }
    }

    #[test]
    fn objective_monotone_in_c() {
        // Larger C relaxes the box, so the optimal dual value cannot drop.
        let (g, rows, y) = separable(120, 6);
        let p = ProblemView::new(&g, &rows, &y);
        let mut last = -f64::MAX;
        for c in [0.1, 0.5, 1.0, 4.0] {
            let sol = solve(
                &p,
                &SolverOptions {
                    c,
                    eps: 1e-5,
                    ..Default::default()
                },
            );
            assert!(
                sol.objective >= last - 1e-6,
                "objective decreased: {} after {last} (C={c})",
                sol.objective
            );
            last = sol.objective;
        }
    }

    #[test]
    fn empty_problem() {
        let g = Mat::zeros(0, 3);
        let rows: Vec<usize> = vec![];
        let y: Vec<f32> = vec![];
        let p = ProblemView::new(&g, &rows, &y);
        let sol = solve(&p, &SolverOptions::default());
        assert!(sol.converged);
        assert_eq!(sol.steps, 0);
    }

    #[test]
    fn zero_feature_rows_are_skipped() {
        // Rows with ⟨G_i,G_i⟩ = 0 cannot move; solver must not NaN.
        let g = Mat::from_vec(3, 2, vec![1., 0., 0., 0., -1., 0.]);
        let rows = vec![0usize, 1, 2];
        let y = vec![1.0f32, 1.0, -1.0];
        let p = ProblemView::new(&g, &rows, &y);
        let sol = solve(&p, &SolverOptions::default());
        assert!(sol.w.iter().all(|x| x.is_finite()));
        assert!(sol.alpha.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let (g, rows, y) = separable(100, 7);
        let p = ProblemView::new(&g, &rows, &y);
        let a = solve(&p, &SolverOptions::default());
        let b = solve(&p, &SolverOptions::default());
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.steps, b.steps);
    }

    #[test]
    fn resume_from_any_snapshot_is_bit_identical() {
        // The checkpoint contract: kill the solve at ANY epoch boundary,
        // resume from the snapshot, and the final model is bit-identical
        // to the uninterrupted run — alpha for alpha, step for step.
        let (g, rows, mut y) = separable(200, 31);
        let mut rng = Rng::new(55);
        for yi in y.iter_mut() {
            if rng.bool(0.2) {
                *yi = -*yi;
            }
        }
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            c: 2.0,
            eps: 1e-4,
            ..Default::default()
        };
        let mut snaps = Vec::new();
        let full = solve_resumable(&p, &opts, None, 1, |s| snaps.push(s.clone()));
        assert!(snaps.len() >= 2, "want several epochs to resume from, got {}", snaps.len());
        for snap in snaps {
            let at = snap.epochs;
            let resumed = solve_resumable(&p, &opts, Some(snap), 0, |_| {});
            assert_eq!(resumed.alpha, full.alpha, "alpha diverged resuming at epoch {at}");
            assert_eq!(resumed.w, full.w, "w diverged resuming at epoch {at}");
            assert_eq!(resumed.steps, full.steps, "steps diverged resuming at epoch {at}");
            assert_eq!(resumed.epochs, full.epochs);
            assert_eq!(resumed.converged, full.converged);
            assert_eq!(resumed.violation, full.violation);
        }
    }

    #[test]
    fn snapshot_interval_and_terminal_epochs_are_respected() {
        let (g, rows, y) = separable(150, 12);
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            eps: 1e-4,
            ..Default::default()
        };
        let mut at = Vec::new();
        let sol = solve_resumable(&p, &opts, None, 2, |s| at.push(s.epochs));
        // Snapshots land on interval multiples and never on the final
        // (terminating) epoch.
        assert!(at.iter().all(|e| e % 2 == 0), "{at:?}");
        assert!(at.iter().all(|&e| e < sol.epochs), "{at:?} vs {}", sol.epochs);
    }

    #[test]
    fn noisy_problem_has_bounded_svs() {
        // With label noise, some α hit the C bound but the solver still
        // converges and the box holds.
        let (g, rows, mut y) = separable(300, 8);
        let mut rng = Rng::new(99);
        for yi in y.iter_mut() {
            if rng.bool(0.1) {
                *yi = -*yi;
            }
        }
        let p = ProblemView::new(&g, &rows, &y);
        let opts = SolverOptions {
            c: 2.0,
            eps: 1e-2,
            max_epochs: 5000,
            ..Default::default()
        };
        let sol = solve(&p, &opts);
        assert!(sol.converged, "violation {}", sol.violation);
        let at_bound = sol
            .alpha
            .iter()
            .filter(|&&a| (a - 2.0).abs() < 1e-6)
            .count();
        assert!(at_bound > 0, "noise should push some alphas to C");
    }
}
