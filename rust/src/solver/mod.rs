//! Stage 2 of LPD-SVM: dual coordinate ascent on the precomputed low-rank
//! features — a *linear* SVM solver over the rows of `G` (paper §4).
//!
//! The dual problem is `max_{α∈[0,C]ⁿ} 1ᵀα − ½ αᵀ Q̃ α` with
//! `Q̃_ij = y_i y_j ⟨G_i, G_j⟩`. Because `Q̃` factors through `G`, a single
//! coordinate step costs `O(B)` via the maintained primal vector
//! `v = Σ_j α_j y_j G_j`:
//!
//!   grad_i = y_i ⟨G_i, v⟩ − 1
//!   α_i ← clip(α_i − grad_i / ⟨G_i,G_i⟩, [0, C])     (truncated Newton)
//!   v  += (α_i^new − α_i^old) y_i G_i
//!
//! plus the paper's "polishing": robust shrinking (remove after k=5
//! unchanged visits, spend an η=5% time budget on re-activation sweeps), a
//! LIBLINEAR-style maximum-KKT-violation stopping rule, and warm starts.
//!
//! Invariants: `α` stays inside `[0, C]ⁿ` and `v` always equals
//! `Σ_j α_j y_j G_j` (maintained incrementally, never recomputed); a
//! `converged` result means the max KKT violation over *all* points —
//! including previously shrunk ones — is below `eps`; visit order is
//! deterministic from the recorded seed; mismatched `warm_alpha` fails
//! fast instead of silently mis-warming.

pub mod block;
pub mod cd;
pub mod shrinking;
pub mod state;
pub mod svr;

pub use block::{solve_blockwise, solve_blockwise_resumable, BlockProblem, BlockSnapshot};
pub use cd::{solve, solve_resumable, Solution, SolverOptions, SolverSnapshot};
pub use state::ProblemView;
pub use svr::{solve_svr, SvrOptions, SvrSolution};
