//! Blockwise dual coordinate ascent over a streaming [`DataSource`] —
//! stage 2 without the resident `G`.
//!
//! The classic solver ([`crate::solver::cd`]) walks rows of a
//! precomputed `G`. Out of core, `G` rows are recomputed on the fly:
//! each epoch streams the active rows in blocks, evaluates the factor
//! chunk for one *stripe* at a time (see [`crate::data::block`] for why
//! stripes, not blocks, are the unit of computation), and runs the same
//! O(rank) coordinate step — gradient from `⟨G_i, v⟩`, truncated-Newton
//! update, incremental `v` maintenance.
//!
//! ## Residual carry (`pred`)
//!
//! Alongside `α` and `v` the solver maintains `pred[i] ≈ ⟨G_i, v⟩` for
//! every subproblem row — the residual prediction carried across blocks
//! and epochs (the `pred_old` of blockwise SVM training). It is updated
//! exactly at each visit (`pred += Δα·y·⟨G_i,G_i⟩` after the axpy, a
//! closed form of the new dot product), refreshed whenever a sweep
//! recomputes a row, and serialized into [`BlockSnapshot`]. Its job is
//! to make shrinking's re-activation sweeps cheap: the η-budget interim
//! sweep first screens shrunk rows against `pred` and only streams
//! feature bytes for rows whose *estimated* violation is at least
//! [`REACT_PREFILTER`]·ε — rows that look KKT-clean from the carried
//! residual cost no I/O at all. Convergence never depends on the
//! estimate: the final sweep that certifies termination recomputes every
//! shrunk row's gradient exactly.
//!
//! ## Bit-identity
//!
//! For a fixed subproblem and seed, the solve trajectory is a function
//! of the stripe sequence only: visit order inside a stripe comes from a
//! stateless per-`(epoch, stripe)` RNG, factor rows are computed per
//! stripe, and sweeps iterate rows in ascending global order. Block
//! boundaries (and hence `--block-budget-mb`, and the choice of
//! in-memory vs sharded source) carry no information, so any budget and
//! any source produce byte-identical models. Kill-and-resume restores
//! [`BlockSnapshot`] — including the mid-epoch stripe cursor and the
//! carried residuals — and replays the identical trajectory.

use crate::data::block::{stripe_of, DataSource};
use crate::linalg::dense::{axpy, dot};
use crate::lowrank::factor::NativeBackend;
use crate::lowrank::stream::StreamFactor;
use crate::solver::cd::violation;
use crate::solver::shrinking::ActiveSet;
use crate::solver::{Solution, SolverOptions};
use crate::util::rng::Rng;
use std::time::Instant;

/// Interim re-activation sweeps only stream rows whose `pred`-estimated
/// violation is at least this fraction of ε. Rows never evaluated this
/// solve have `pred == 0`, estimate their violation at 1, and therefore
/// always qualify — the filter can delay a re-activation but never
/// starve one, and the terminal sweep is exact regardless.
pub const REACT_PREFILTER: f64 = 0.5;

/// One binary subproblem phrased against a streaming source: the rows
/// (ascending global ids), their ±1 labels, and the stage-1 factor that
/// turns feature rows into `G` rows.
pub struct BlockProblem<'a> {
    pub source: &'a dyn DataSource,
    pub factor: &'a StreamFactor,
    /// Global row ids of the subproblem, strictly ascending.
    pub rows: Vec<usize>,
    /// Label per local variable, aligned with `rows`.
    pub y: Vec<f32>,
    /// Byte budget handed to the source per streaming pass (0 = one block).
    pub budget_bytes: usize,
    pub backend: NativeBackend,
}

impl<'a> BlockProblem<'a> {
    pub fn new(
        source: &'a dyn DataSource,
        factor: &'a StreamFactor,
        rows: Vec<usize>,
        y: Vec<f32>,
        budget_bytes: usize,
        backend: NativeBackend,
    ) -> BlockProblem<'a> {
        assert_eq!(rows.len(), y.len(), "rows/labels length mismatch");
        debug_assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must be ascending");
        debug_assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        BlockProblem { source, factor, rows, y, budget_bytes, backend }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Everything the blockwise loop carries across a block boundary. Unlike
/// the classic [`crate::solver::SolverSnapshot`] this can be captured
/// *mid-epoch*: `cursor` is the next global stripe of the running epoch
/// (0 = at an epoch boundary) and `flagged`/`epoch_max_viol` hold the
/// epoch-so-far shrink flags and KKT maximum. No RNG state is stored —
/// visit permutations come from stateless per-`(epoch, stripe)` seeds.
#[derive(Clone, Debug)]
pub struct BlockSnapshot {
    /// Completed epochs.
    pub epochs: u64,
    /// Next global stripe to process in the current epoch (0 = fresh).
    pub cursor: u64,
    pub steps: u64,
    pub active_work: u64,
    pub check_work: u64,
    /// Maximum KKT violation seen so far in the running epoch.
    pub epoch_max_viol: f64,
    pub alpha: Vec<f32>,
    pub v: Vec<f32>,
    /// Carried residual predictions `pred[i] ≈ ⟨G_i, v⟩`.
    pub pred: Vec<f32>,
    pub active: Vec<u32>,
    pub unchanged: Vec<u8>,
    pub inactive: Vec<u32>,
    /// Rows flagged for shrinking so far in the running epoch.
    pub flagged: Vec<u32>,
    pub total_shrunk: u64,
    pub total_reactivated: u64,
}

/// Stateless per-(epoch, stripe) permutation seed (splitmix64-style
/// finalizer) — resuming mid-epoch re-derives the exact visit order of
/// every remaining stripe without carrying RNG state.
fn stripe_seed(seed: u64, epoch: u64, stripe: u64) -> u64 {
    let mut z = seed
        .wrapping_add(epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(stripe.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stream `⟨G_i, v⟩` for every masked row, in ascending global order.
/// The factor chunk is evaluated per stripe, keeping the values (and
/// their float rounding) independent of the block budget.
fn stream_dots(
    p: &BlockProblem<'_>,
    mask: &[bool],
    v: &[f32],
    f: &mut dyn FnMut(usize, f32),
) -> anyhow::Result<()> {
    p.source.for_each_block(p.budget_bytes, Some(mask), &mut |blk| {
        for (_, s, e) in blk.stripes() {
            let g = p.factor.g_rows(&p.backend, blk.x, &blk.local[s..e])?;
            for i in s..e {
                f(blk.rows[i], dot(g.row(i - s), v));
            }
        }
        Ok(())
    })
}

/// Train a linear SVM blockwise. See [`solve_blockwise_resumable`].
pub fn solve_blockwise(p: &BlockProblem<'_>, opts: &SolverOptions) -> anyhow::Result<Solution> {
    solve_blockwise_resumable(p, opts, None, 0, |_| {})
}

/// [`solve_blockwise`] with crash-safe checkpointing hooks, mirroring
/// [`crate::solver::solve_resumable`]: `resume` restarts from a captured
/// [`BlockSnapshot`] (possibly mid-epoch), and when `checkpoint_every >
/// 0`, `sink` receives a snapshot after every streamed block of each
/// `checkpoint_every`-th epoch plus one at that epoch's boundary.
/// Persisting snapshots is the caller's business
/// ([`crate::coordinator::checkpoint::CheckpointCtx::solve_blockwise`]).
pub fn solve_blockwise_resumable(
    p: &BlockProblem<'_>,
    opts: &SolverOptions,
    resume: Option<BlockSnapshot>,
    checkpoint_every: usize,
    mut sink: impl FnMut(&BlockSnapshot),
) -> anyhow::Result<Solution> {
    let m = p.len();
    let rank = p.factor.rank;
    let n_src = p.source.n_rows();
    anyhow::ensure!(
        opts.warm_alpha.is_none(),
        "the blockwise solver does not support warm starts"
    );
    let c = opts.c as f32;
    // lint: allow(determinism-domain) — feeds only the train_secs stat
    let t_start = Instant::now();

    let mut alpha = vec![0.0f32; m];
    let mut v = vec![0.0f32; rank];
    let mut pred = vec![0.0f32; m];
    // Diagonal ⟨G_i,G_i⟩, filled lazily on first visit (computing it up
    // front would cost a full streaming pass). Pure function of the row,
    // so laziness is not state: a resume recomputes identical values.
    let mut diag = vec![f32::NAN; m];
    let mut active = ActiveSet::new(m, opts.shrink_k);
    let mut flagged: Vec<u32> = Vec::new();

    let mut steps: u64 = 0;
    let mut epochs: u64 = 0;
    let mut cursor: u64 = 0;
    let mut max_viol = 0.0f32;
    let mut converged = false;
    let mut final_violation = f64::MAX;
    let mut active_work: u64 = 0;
    let mut check_work: u64 = 0;

    if let Some(snap) = resume {
        anyhow::ensure!(
            snap.alpha.len() == m && snap.unchanged.len() == m && snap.pred.len() == m,
            "BlockSnapshot has {} variables but the problem has {m} — a \
             checkpoint only resumes the exact problem it was taken from",
            snap.alpha.len()
        );
        anyhow::ensure!(
            snap.v.len() == rank,
            "BlockSnapshot v has dim {} but the factor has rank {rank}",
            snap.v.len()
        );
        alpha = snap.alpha;
        v = snap.v;
        pred = snap.pred;
        active = ActiveSet::from_snapshot(
            snap.active,
            snap.unchanged,
            snap.inactive,
            snap.total_shrunk,
            snap.total_reactivated,
            opts.shrink_k,
        );
        flagged = snap.flagged;
        steps = snap.steps;
        epochs = snap.epochs;
        cursor = snap.cursor;
        max_viol = snap.epoch_max_viol as f32;
        active_work = snap.active_work;
        check_work = snap.check_work;
    }

    // Global→local variable map for the sweep callbacks.
    let mut global_to_local = vec![u32::MAX; n_src];
    for (li, &g) in p.rows.iter().enumerate() {
        global_to_local[g] = li as u32;
    }

    if m == 0 {
        return Ok(Solution {
            alpha,
            w: v,
            objective: 0.0,
            steps: 0,
            epochs: 0,
            sv_count: 0,
            converged: true,
            violation: 0.0,
            train_secs: t_start.elapsed().as_secs_f64(),
            final_active: 0,
        });
    }

    let mut solve_span = crate::obs::Span::new("solve");
    solve_span.arg("n", m as f64);
    solve_span.arg("blockwise", 1.0);

    while epochs < opts.max_epochs as u64 {
        let cur = epochs; // 0-based index of the epoch now running
        let snapshot_epoch = checkpoint_every > 0 && (cur + 1) % checkpoint_every as u64 == 0;
        let mut epoch_span = crate::obs::Span::new("solve.epoch");
        let mut epoch_reactivated: u64 = 0;

        // --- main pass: stream the active rows of stripes >= cursor ---
        let mut wanted = vec![false; n_src];
        for &li in &active.active {
            let g = p.rows[li as usize];
            if (stripe_of(g) as u64) >= cursor {
                wanted[g] = true;
            }
        }
        p.source.for_each_block(p.budget_bytes, Some(&wanted), &mut |blk| {
            for (sid, s, e) in blk.stripes() {
                let g_mat = p.factor.g_rows(&p.backend, blk.x, &blk.local[s..e])?;
                // Per-stripe permutation: the paper's randomized
                // round-robin, scoped to the stripe so the order is a
                // function of (seed, epoch, stripe) alone.
                let k = e - s;
                let mut order: Vec<u32> = (0..k as u32).collect();
                let mut rng = Rng::new(stripe_seed(opts.seed, cur, sid as u64));
                rng.shuffle(&mut order);
                for &pos in &order {
                    let gi = g_mat.row(pos as usize);
                    let iu = global_to_local[blk.rows[s + pos as usize]] as usize;
                    let yi = p.y[iu];
                    let dotv = dot(gi, &v);
                    pred[iu] = dotv;
                    let grad = yi * dotv - 1.0;
                    let a_old = alpha[iu];
                    let viol = violation(grad, a_old, c);
                    if viol > max_viol {
                        max_viol = viol;
                    }
                    if diag[iu].is_nan() {
                        diag[iu] = dot(gi, gi);
                    }
                    let d = diag[iu];
                    let mut changed = false;
                    if viol > 1e-12 && d > 0.0 {
                        let a_new = (a_old - grad / d).clamp(0.0, c);
                        let delta = a_new - a_old;
                        if delta != 0.0 {
                            alpha[iu] = a_new;
                            axpy(delta * yi, gi, &mut v);
                            // Exact closed form of the post-update dot:
                            // ⟨G_i, v + Δ·y·G_i⟩ = dotv + Δ·y·⟨G_i,G_i⟩.
                            pred[iu] = dotv + delta * yi * d;
                            changed = true;
                        }
                    }
                    steps += 1;
                    active_work += 1;
                    if opts.shrinking && active.visit(iu as u32, changed) {
                        flagged.push(iu as u32);
                    }
                }
            }
            if snapshot_epoch {
                let next_cursor = stripe_of(*blk.rows.last().unwrap()) as u64 + 1;
                let (a, u, i, ts, tr) = active.snapshot();
                sink(&BlockSnapshot {
                    epochs: cur,
                    cursor: next_cursor,
                    steps,
                    active_work,
                    check_work,
                    epoch_max_viol: max_viol as f64,
                    alpha: alpha.clone(),
                    v: v.clone(),
                    pred: pred.clone(),
                    active: a,
                    unchanged: u,
                    inactive: i,
                    flagged: flagged.clone(),
                    total_shrunk: ts,
                    total_reactivated: tr,
                });
            }
            Ok(())
        })?;

        // --- epoch boundary ---
        epochs += 1;
        cursor = 0;
        if opts.shrinking {
            active.shrink(&flagged);
        }
        flagged.clear();

        let active_converged = (max_viol as f64) < opts.eps;
        epoch_span.arg("epoch", epochs as f64);
        epoch_span.arg("kkt", max_viol as f64);
        epoch_span.arg("active", active.n_active() as f64);

        if active_converged {
            // Exact verification sweep over every shrunk row — the
            // estimate filter below never gates termination.
            let mut violators: Vec<u32> = Vec::new();
            let mut max_inactive_viol = 0.0f32;
            if !active.inactive.is_empty() {
                let mut mask = vec![false; n_src];
                for &li in &active.inactive {
                    mask[p.rows[li as usize]] = true;
                }
                check_work += active.inactive.len() as u64;
                stream_dots(p, &mask, &v, &mut |g, dotv| {
                    let iu = global_to_local[g] as usize;
                    pred[iu] = dotv;
                    let viol = violation(p.y[iu] * dotv - 1.0, alpha[iu], c);
                    if viol > max_inactive_viol {
                        max_inactive_viol = viol;
                    }
                    if (viol as f64) >= opts.eps {
                        violators.push(iu as u32);
                    }
                })?;
                epoch_reactivated += violators.len() as u64;
                active.reactivate_all(&violators);
            }
            if violators.is_empty() {
                final_violation = max_viol.max(max_inactive_viol) as f64;
                converged = true;
                break;
            }
            // Violators re-activated: the violation just measured is
            // stale the moment we continue (mirrors the classic solver).
            final_violation = f64::MAX;
        } else if opts.shrinking
            && !active.inactive.is_empty()
            && (check_work as f64) < opts.reactivate_frac * (active_work + check_work) as f64
        {
            // η-budget interim sweep, screened by the carried residuals:
            // only rows whose estimated violation clears the prefilter
            // threshold cost streaming I/O.
            let mut mask = vec![false; n_src];
            let mut n_cand: u64 = 0;
            for &li in &active.inactive {
                let iu = li as usize;
                let est = violation(p.y[iu] * pred[iu] - 1.0, alpha[iu], c);
                if (est as f64) >= REACT_PREFILTER * opts.eps {
                    mask[p.rows[iu]] = true;
                    n_cand += 1;
                }
            }
            if n_cand > 0 {
                let mut violators: Vec<u32> = Vec::new();
                stream_dots(p, &mask, &v, &mut |g, dotv| {
                    let iu = global_to_local[g] as usize;
                    pred[iu] = dotv;
                    let viol = violation(p.y[iu] * dotv - 1.0, alpha[iu], c);
                    if (viol as f64) >= opts.eps {
                        violators.push(iu as u32);
                    }
                })?;
                epoch_reactivated += violators.len() as u64;
                active.reactivate_all(&violators);
            }
            check_work += n_cand;
        }
        epoch_span.arg("reactivated", epoch_reactivated as f64);
        drop(epoch_span);

        max_viol = 0.0;
        if snapshot_epoch && epochs < opts.max_epochs as u64 {
            let (a, u, i, ts, tr) = active.snapshot();
            sink(&BlockSnapshot {
                epochs,
                cursor: 0,
                steps,
                active_work,
                check_work,
                epoch_max_viol: 0.0,
                alpha: alpha.clone(),
                v: v.clone(),
                pred: pred.clone(),
                active: a,
                unchanged: u,
                inactive: i,
                flagged: Vec::new(),
                total_shrunk: ts,
                total_reactivated: tr,
            });
        }
    }

    if final_violation == f64::MAX {
        // Terminated on the epoch cap — one exact pass for the true
        // violation of the final iterate, in ascending global order.
        let mut mask = vec![false; n_src];
        for &g in &p.rows {
            mask[g] = true;
        }
        let mut mv = 0.0f32;
        stream_dots(p, &mask, &v, &mut |g, dotv| {
            let iu = global_to_local[g] as usize;
            pred[iu] = dotv;
            mv = mv.max(violation(p.y[iu] * dotv - 1.0, alpha[iu], c));
        })?;
        final_violation = mv as f64;
        converged = final_violation < opts.eps;
    }

    solve_span.arg("epochs", epochs as f64);
    solve_span.arg("steps", steps as f64);
    solve_span.arg("converged", converged as u8 as f64);
    solve_span.arg("kkt", final_violation);
    crate::log_debug!(
        "solver",
        "blockwise n={m} epochs={epochs} steps={steps} converged={converged} \
         kkt={final_violation:.3e} shrunk={} reactivated={}",
        active.total_shrunk,
        active.total_reactivated
    );

    let sum_a: f64 = alpha.iter().map(|&a| a as f64).sum();
    let vv: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    Ok(Solution {
        objective: sum_a - 0.5 * vv,
        sv_count: alpha.iter().filter(|&&a| a > 0.0).count(),
        final_active: active.n_active(),
        alpha,
        w: v,
        steps,
        epochs: epochs as usize,
        converged,
        violation: final_violation,
        train_secs: t_start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::block::MemorySource;
    use crate::data::synth::{FeatureStyle, SynthSpec};
    use crate::data::Dataset;
    use crate::kernel::Kernel;
    use crate::lowrank::factor::Stage1Config;
    use crate::util::timer::StageClock;

    fn dataset(n: usize, seed: u64) -> Dataset {
        SynthSpec {
            name: "t".into(),
            n,
            p: 8,
            n_classes: 2,
            sep: 1.5,
            latent: 4,
            noise: 1.0,
            style: FeatureStyle::Dense,
            seed,
        }
        .generate()
    }

    fn factor_for(src: &dyn DataSource) -> StreamFactor {
        let cfg = Stage1Config { budget: 24, ..Default::default() };
        StreamFactor::compute(src, Kernel::gaussian(0.2), &cfg, 0, &mut StageClock::new()).unwrap()
    }

    fn problem<'a>(
        src: &'a dyn DataSource,
        factor: &'a StreamFactor,
        budget: usize,
    ) -> BlockProblem<'a> {
        let rows: Vec<usize> = (0..src.n_rows()).collect();
        let y: Vec<f32> =
            src.labels().iter().map(|&l| if l == 1 { 1.0 } else { -1.0 }).collect();
        BlockProblem::new(src, factor, rows, y, budget, NativeBackend::default())
    }

    #[test]
    fn solves_and_respects_box() {
        let ds = dataset(2600, 1);
        let src = MemorySource::new(&ds);
        let factor = factor_for(&src);
        let p = problem(&src, &factor, 0);
        let opts = SolverOptions { c: 0.7, eps: 1e-2, ..Default::default() };
        let sol = solve_blockwise(&p, &opts).unwrap();
        assert!(sol.converged, "violation {}", sol.violation);
        assert!(sol.violation < opts.eps);
        for &a in &sol.alpha {
            assert!((0.0..=0.7 + 1e-6).contains(&a), "alpha {a} outside box");
        }
        assert!(sol.sv_count > 0);
    }

    #[test]
    fn bit_identical_across_block_budgets() {
        let ds = dataset(2600, 2);
        let src = MemorySource::new(&ds);
        let factor = factor_for(&src);
        let opts = SolverOptions { eps: 1e-3, ..Default::default() };
        let reference = solve_blockwise(&problem(&src, &factor, 0), &opts).unwrap();
        for budget in [8_000usize, 30_000, 1 << 30] {
            let sol = solve_blockwise(&problem(&src, &factor, budget), &opts).unwrap();
            assert_eq!(sol.alpha, reference.alpha, "budget {budget}");
            assert_eq!(sol.w, reference.w, "budget {budget}");
            assert_eq!(sol.steps, reference.steps, "budget {budget}");
            assert_eq!(sol.violation, reference.violation, "budget {budget}");
        }
    }

    #[test]
    fn resume_from_any_snapshot_is_bit_identical() {
        let ds = dataset(2600, 3);
        let src = MemorySource::new(&ds);
        let factor = factor_for(&src);
        // Small budget → several blocks per epoch → mid-epoch snapshots.
        let opts =
            SolverOptions { c: 2.0, eps: 1e-3, max_epochs: 9, ..Default::default() };
        let mut snaps = Vec::new();
        let p = problem(&src, &factor, 10_000);
        let full = solve_blockwise_resumable(&p, &opts, None, 3, |s| snaps.push(s.clone()))
            .unwrap();
        let mid_epoch = snaps.iter().filter(|s| s.cursor != 0).count();
        assert!(mid_epoch > 0, "want mid-epoch snapshots, got cursors {:?}",
            snaps.iter().map(|s| s.cursor).collect::<Vec<_>>());
        for snap in snaps {
            let at = (snap.epochs, snap.cursor);
            let resumed =
                solve_blockwise_resumable(&p, &opts, Some(snap), 0, |_| {}).unwrap();
            assert_eq!(resumed.alpha, full.alpha, "alpha diverged resuming at {at:?}");
            assert_eq!(resumed.w, full.w, "w diverged resuming at {at:?}");
            assert_eq!(resumed.steps, full.steps, "steps diverged resuming at {at:?}");
            assert_eq!(resumed.violation, full.violation);
        }
    }

    #[test]
    fn shrinking_matches_no_shrinking_objective() {
        let ds = dataset(2100, 4);
        let src = MemorySource::new(&ds);
        let factor = factor_for(&src);
        let base = SolverOptions { eps: 1e-4, ..Default::default() };
        let with = solve_blockwise(&problem(&src, &factor, 20_000), &base).unwrap();
        let without = solve_blockwise(
            &problem(&src, &factor, 20_000),
            &SolverOptions { shrinking: false, ..base },
        )
        .unwrap();
        assert!(
            (with.objective - without.objective).abs()
                < 1e-3 * (1.0 + without.objective.abs()),
            "{} vs {}",
            with.objective,
            without.objective
        );
    }

    #[test]
    fn empty_subproblem_is_trivially_converged() {
        let ds = dataset(64, 5);
        let src = MemorySource::new(&ds);
        let factor = factor_for(&src);
        let p = BlockProblem::new(&src, &factor, vec![], vec![], 0, NativeBackend::default());
        let sol = solve_blockwise(&p, &SolverOptions::default()).unwrap();
        assert!(sol.converged);
        assert_eq!(sol.steps, 0);
        assert_eq!(sol.w.len(), factor.rank);
    }
}
