//! ε-insensitive support vector *regression* on the low-rank features.
//!
//! The paper (§2) notes the decision function "is directly suitable for
//! regression tasks" and that the dual problems for regression "are of a
//! similar form"; this module supplies that head. We solve the L1-SVR dual
//! over `G` with one variable β_i ∈ [−C, C] per point (the standard
//! α⁺−α⁻ folding):
//!
//!   max_β  −½ βᵀK̃β + βᵀy − ε‖β‖₁,   β ∈ [−C, C]ⁿ,  K̃ = G Gᵀ
//!
//! Coordinate ascent step (LIBLINEAR's L1-SVR update, O(B) via the
//! maintained `w = Σ β_i G_i`): with g = ⟨G_i, w⟩ − y_i,
//!   β⁺-direction gradient: g + ε,  β⁻-direction: g − ε,
//! soft-thresholded Newton step and clip to the box.

use crate::linalg::dense::{axpy, dot};
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Options for an SVR training run.
#[derive(Clone, Debug)]
pub struct SvrOptions {
    pub c: f64,
    /// Width of the ε-insensitive tube.
    pub epsilon_tube: f64,
    /// KKT stopping tolerance.
    pub eps: f64,
    pub max_epochs: usize,
    pub seed: u64,
}

impl Default for SvrOptions {
    fn default() -> Self {
        SvrOptions {
            c: 1.0,
            epsilon_tube: 0.1,
            eps: 1e-3,
            max_epochs: 1000,
            seed: 0x5B,
        }
    }
}

/// Trained SVR head.
#[derive(Clone, Debug)]
pub struct SvrSolution {
    pub beta: Vec<f32>,
    /// Weights in G-space; prediction is `⟨g(x), w⟩`.
    pub w: Vec<f32>,
    pub converged: bool,
    pub epochs: usize,
    pub sv_count: usize,
    pub violation: f64,
}

/// Violation of the SVR KKT conditions for variable `i`, where
/// `g = ⟨G_i, w⟩ − y_i` is the smooth-part gradient. Minimisation form:
/// `f(β) = ½βᵀK̃β − βᵀy + ε‖β‖₁` with box `[−C, C]`.
#[inline]
fn svr_violation(g: f32, beta: f32, c: f32, eps_tube: f32) -> f32 {
    let gp = g + eps_tube; // ∂f for β > 0 moves
    let gn = g - eps_tube; // ∂f for β < 0 moves
    if beta >= c {
        gp.max(0.0) // improvement only by decreasing β
    } else if beta <= -c {
        (-gn).max(0.0)
    } else if beta > 0.0 {
        gp.abs()
    } else if beta < 0.0 {
        gn.abs()
    } else {
        // At 0: moving up helps if gp < 0, down if gn > 0.
        (-gp).max(0.0).max(gn.max(0.0))
    }
}

/// Train ε-SVR over rows of `g_mat` with targets `y`.
pub fn solve_svr(g_mat: &Mat, y: &[f32], opts: &SvrOptions) -> SvrSolution {
    let n = g_mat.rows;
    assert_eq!(n, y.len());
    let c = opts.c as f32;
    let tube = opts.epsilon_tube as f32;
    let mut beta = vec![0.0f32; n];
    let mut w = vec![0.0f32; g_mat.cols];
    let diag: Vec<f32> = (0..n)
        .map(|i| {
            let r = g_mat.row(i);
            dot(r, r)
        })
        .collect();
    let mut rng = Rng::new(opts.seed);
    let mut order: Vec<u32> = (0..n as u32).collect();

    let mut epochs = 0;
    let mut converged = false;
    let mut max_viol = 0.0f32;
    while epochs < opts.max_epochs {
        epochs += 1;
        rng.shuffle(&mut order);
        max_viol = 0.0;
        for &iu in &order {
            let i = iu as usize;
            let d = diag[i];
            if d <= 0.0 {
                continue;
            }
            let gi = g_mat.row(i);
            let g = dot(gi, &w) - y[i];
            let b_old = beta[i];
            max_viol = max_viol.max(svr_violation(g, b_old, c, tube));
            // Exact coordinate minimiser of the quadratic + ε|·| along i:
            // soft-threshold the unconstrained Newton point, then box-clip.
            // (g is the gradient of the smooth part at b_old.)
            let u = b_old - g / d;
            let t = tube / d;
            let b_new = if u > t {
                (u - t).min(c)
            } else if u < -t {
                (u + t).max(-c)
            } else {
                0.0
            };
            let delta = b_new - b_old;
            if delta != 0.0 {
                beta[i] = b_new;
                axpy(delta, gi, &mut w);
            }
        }
        if (max_viol as f64) < opts.eps {
            converged = true;
            break;
        }
    }

    SvrSolution {
        sv_count: beta.iter().filter(|&&b| b != 0.0).count(),
        beta,
        w,
        converged,
        epochs,
        violation: max_viol as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// y = 2·g0 − g1 + noise, linear in feature space.
    fn linear_problem(n: usize, noise: f32, seed: u64) -> (Mat, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut g = Mat::zeros(n, 3);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            g.set(i, 0, a);
            g.set(i, 1, b);
            g.set(i, 2, 1.0); // bias feature
            y.push(2.0 * a - b + noise * rng.normal() as f32);
        }
        (g, y)
    }

    #[test]
    fn fits_linear_function() {
        let (g, y) = linear_problem(300, 0.0, 1);
        let sol = solve_svr(
            &g,
            &y,
            &SvrOptions {
                c: 10.0,
                epsilon_tube: 0.05,
                ..Default::default()
            },
        );
        let preds = g.matvec(&sol.w);
        let mae: f32 =
            preds.iter().zip(&y).map(|(p, t)| (p - t).abs()).sum::<f32>() / y.len() as f32;
        assert!(mae < 0.1, "MAE {mae}");
        assert!((sol.w[0] - 2.0).abs() < 0.2, "w0 {}", sol.w[0]);
        assert!((sol.w[1] + 1.0).abs() < 0.2, "w1 {}", sol.w[1]);
    }

    #[test]
    fn beta_in_box() {
        let (g, y) = linear_problem(200, 0.5, 2);
        let opts = SvrOptions {
            c: 0.3,
            ..Default::default()
        };
        let sol = solve_svr(&g, &y, &opts);
        for &b in &sol.beta {
            assert!(b.abs() <= 0.3 + 1e-5, "beta {b} outside box");
        }
    }

    #[test]
    fn wide_tube_gives_sparse_solution() {
        let (g, y) = linear_problem(200, 0.1, 3);
        let narrow = solve_svr(
            &g,
            &y,
            &SvrOptions {
                epsilon_tube: 0.01,
                ..Default::default()
            },
        );
        let wide = solve_svr(
            &g,
            &y,
            &SvrOptions {
                epsilon_tube: 1.0,
                ..Default::default()
            },
        );
        assert!(
            wide.sv_count < narrow.sv_count,
            "wide tube {} should have fewer SVs than narrow {}",
            wide.sv_count,
            narrow.sv_count
        );
    }

    #[test]
    fn predictions_within_tube_on_clean_data() {
        let (g, y) = linear_problem(150, 0.0, 4);
        let tube = 0.2;
        let sol = solve_svr(
            &g,
            &y,
            &SvrOptions {
                c: 100.0,
                epsilon_tube: tube,
                eps: 1e-4,
                max_epochs: 3000,
                ..Default::default()
            },
        );
        assert!(sol.converged);
        let preds = g.matvec(&sol.w);
        for (p, t) in preds.iter().zip(&y) {
            assert!(
                (p - t).abs() <= tube as f32 + 0.05,
                "residual {} beyond tube",
                (p - t).abs()
            );
        }
    }

    #[test]
    fn end_to_end_kernel_regression() {
        // Nonlinear target through the full stage-1 + SVR pipeline:
        // y = sin(2 x0) on 1-D inputs, Gaussian kernel features.
        use crate::data::sparse::SparseMatrix;
        use crate::kernel::Kernel;
        use crate::lowrank::factor::NativeBackend;
        use crate::lowrank::{LowRankFactor, Stage1Config};
        use crate::util::timer::StageClock;
        let n = 400;
        let mut rows = Vec::new();
        let mut y = Vec::new();
        let mut rng = Rng::new(9);
        for _ in 0..n {
            let x = rng.range_f64(-2.0, 2.0) as f32;
            rows.push(vec![(0u32, x)]);
            y.push((2.0 * x).sin());
        }
        let x = SparseMatrix::from_rows(1, &rows);
        let mut clock = StageClock::new();
        let factor = LowRankFactor::compute(
            &x,
            Kernel::gaussian(2.0),
            &Stage1Config {
                budget: 50,
                ..Default::default()
            },
            &NativeBackend::default(),
            &mut clock,
        )
        .unwrap();
        let sol = solve_svr(
            &factor.g,
            &y,
            &SvrOptions {
                c: 10.0,
                epsilon_tube: 0.02,
                max_epochs: 2000,
                ..Default::default()
            },
        );
        let preds = factor.g.matvec(&sol.w);
        let mae: f32 =
            preds.iter().zip(&y).map(|(p, t)| (p - t).abs()).sum::<f32>() / n as f32;
        assert!(mae < 0.08, "kernel SVR MAE {mae}");
    }
}
