//! Problem view and dual state for the CD solver.

use crate::linalg::dense::{axpy, dot};
use crate::linalg::Mat;

/// A (possibly row-subset) view of the linear SVM problem over `G`.
///
/// `rows[i]` is the row of `g` backing local variable `i`; `y[i] ∈ {−1,+1}`
/// its label. OVO sub-problems and CV folds are views into the one shared
/// `G` — the paper's G-reuse across folds/pairs relies on this being
/// copy-free.
pub struct ProblemView<'a> {
    pub g: &'a Mat,
    pub rows: &'a [usize],
    pub y: &'a [f32],
}

impl<'a> ProblemView<'a> {
    pub fn new(g: &'a Mat, rows: &'a [usize], y: &'a [f32]) -> Self {
        assert_eq!(rows.len(), y.len(), "rows/labels length mismatch");
        debug_assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        ProblemView { g, rows, y }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.g.cols
    }

    #[inline]
    pub fn feature_row(&self, i: usize) -> &[f32] {
        self.g.row(self.rows[i])
    }

    /// Diagonal `Q̃_ii = ⟨G_i, G_i⟩` for every local variable.
    pub fn diag(&self) -> Vec<f32> {
        self.rows
            .iter()
            .map(|&r| {
                let row = self.g.row(r);
                dot(row, row)
            })
            .collect()
    }
}

/// Dual variables plus the maintained primal vector `v = Σ αᵢ yᵢ Gᵢ`.
pub struct DualState {
    pub alpha: Vec<f32>,
    pub v: Vec<f32>,
}

impl DualState {
    /// Cold start: α = 0, v = 0.
    pub fn zeros(n: usize, dim: usize) -> Self {
        DualState {
            alpha: vec![0.0; n],
            v: vec![0.0; dim],
        }
    }

    /// Warm start from a previous α (clipped into the new box `[0, C]`);
    /// `v` is rebuilt in one `O(n·B)` pass — cheap relative to training and
    /// exactly what the paper's C-grid warm start does.
    pub fn warm(problem: &ProblemView, mut alpha: Vec<f32>, c: f32) -> Self {
        assert_eq!(alpha.len(), problem.len(), "warm-start size mismatch");
        let mut v = vec![0.0f32; problem.dim()];
        for i in 0..problem.len() {
            alpha[i] = alpha[i].clamp(0.0, c);
            if alpha[i] != 0.0 {
                axpy(alpha[i] * problem.y[i], problem.feature_row(i), &mut v);
            }
        }
        DualState { alpha, v }
    }

    /// Dual objective `D(α) = Σα − ½‖v‖²`.
    pub fn objective(&self) -> f64 {
        let sum_a: f64 = self.alpha.iter().map(|&a| a as f64).sum();
        let vv: f64 = self.v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        sum_a - 0.5 * vv
    }

    /// Number of support vectors (α > 0).
    pub fn sv_count(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_g() -> Mat {
        Mat::from_vec(4, 2, vec![1., 0., 0., 1., -1., 0., 0., -1.])
    }

    #[test]
    fn view_selects_rows() {
        let g = toy_g();
        let rows = vec![2usize, 0];
        let y = vec![1.0f32, -1.0];
        let p = ProblemView::new(&g, &rows, &y);
        assert_eq!(p.len(), 2);
        assert_eq!(p.feature_row(0), &[-1., 0.]);
        assert_eq!(p.feature_row(1), &[1., 0.]);
    }

    #[test]
    fn diag_is_row_norms() {
        let g = toy_g();
        let rows = vec![0usize, 1, 2, 3];
        let y = vec![1.0f32, 1.0, -1.0, -1.0];
        let p = ProblemView::new(&g, &rows, &y);
        assert_eq!(p.diag(), vec![1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn warm_start_rebuilds_v() {
        let g = toy_g();
        let rows = vec![0usize, 1];
        let y = vec![1.0f32, -1.0];
        let p = ProblemView::new(&g, &rows, &y);
        let s = DualState::warm(&p, vec![0.5, 2.0], 1.0); // 2.0 clipped to 1.0
        assert_eq!(s.alpha, vec![0.5, 1.0]);
        // v = 0.5*1*[1,0] + 1.0*(-1)*[0,1] = [0.5, -1.0]
        assert_eq!(s.v, vec![0.5, -1.0]);
    }

    #[test]
    fn objective_matches_formula() {
        let g = toy_g();
        let rows = vec![0usize, 2];
        let y = vec![1.0f32, 1.0];
        let p = ProblemView::new(&g, &rows, &y);
        let s = DualState::warm(&p, vec![1.0, 1.0], 2.0);
        // v = [1,0] + [-1,0] = [0,0]; D = 2 - 0 = 2
        assert_eq!(s.objective(), 2.0);
        assert_eq!(s.sv_count(), 2);
    }
}
