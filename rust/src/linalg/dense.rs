//! Row-major dense `f32` matrix with the operations the solver needs:
//! blocked GEMM, transposed products, row views, and a few vector
//! primitives (`dot`, `axpy`) shared with the CD hot loop.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self @ other` — cache-blocked i-k-j GEMM. Row-major friendly: the
    /// inner loop is a contiguous axpy over the output row, which the
    /// compiler auto-vectorises.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    axpy(a, brow, orow);
                }
            }
        }
        out
    }

    /// `self @ otherᵀ` — rows of both operands are contiguous, so each
    /// output entry is a straight dot product. Used for Gram blocks.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(a, other.row(j));
            }
        }
        out
    }

    /// `self @ v` for a vector `v` (len = cols).
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// Squared L2 norm of every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| dot(self.row(i), self.row(i))).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertically stack `self` on top of `other`.
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Maximum absolute entry-wise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Contiguous dot product — the single hottest primitive in the whole
/// solver (called once per CD step with `len = B`). Dispatches to an
/// AVX2+FMA kernel when the CPU supports it (the x86-64 *baseline* target
/// only guarantees SSE2, so compile-time autovectorisation alone leaves
/// half the FLOPs on the table — see EXPERIMENTS.md §Perf iteration 3).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// Portable 8-lane accumulation: independent partial sums break the
/// sequential FP dependency chain and map onto SSE lanes.
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += ao[l] * bo[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 yes, 2 no
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma");
            STATE.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

/// AVX2+FMA dot: 4×8-lane accumulators (32 floats/iter) hide FMA latency.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 8)),
            _mm256_loadu_ps(bp.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 16)),
            _mm256_loadu_ps(bp.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 24)),
            _mm256_loadu_ps(bp.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let sum4 = _mm_add_ps(hi, lo);
    let sum2 = _mm_add_ps(sum4, _mm_movehl_ps(sum4, sum4));
    let sum1 = _mm_add_ss(sum2, _mm_shuffle_ps(sum2, sum2, 0b01));
    let mut s = _mm_cvtss_f32(sum1);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `y += a * x` over contiguous slices — the CD step's weight update.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            unsafe { axpy_avx2(a, x, y) };
            return;
        }
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = x.len();
    let av = _mm256_set1_ps(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 16 <= n {
        let y0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        let y1 = _mm256_fmadd_ps(
            av,
            _mm256_loadu_ps(xp.add(i + 8)),
            _mm256_loadu_ps(yp.add(i + 8)),
        );
        _mm256_storeu_ps(yp.add(i), y0);
        _mm256_storeu_ps(yp.add(i + 8), y1);
        i += 16;
    }
    while i + 8 <= n {
        let y0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        _mm256_storeu_ps(yp.add(i), y0);
        i += 8;
    }
    while i < n {
        *y.get_unchecked_mut(i) += a * x.get_unchecked(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let i4 = Mat::eye(4);
        assert_eq!(a.matmul(&i4), a);
        assert_eq!(i4.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_matches_matmul() {
        let a = Mat::from_fn(5, 7, |i, j| ((i * 13 + j * 7) % 5) as f32 - 2.0);
        let b = Mat::from_fn(6, 7, |i, j| ((i * 3 + j) % 4) as f32 - 1.5);
        let via_t = a.matmul(&b.transpose());
        let direct = a.matmul_nt(&b);
        assert!(via_t.max_abs_diff(&direct) < 1e-5);
    }

    #[test]
    fn matmul_blocked_matches_naive_large() {
        // Exercise the BK blocking boundary (k > 64).
        let a = Mat::from_fn(9, 130, |i, j| ((i + j) % 7) as f32 * 0.25 - 0.5);
        let b = Mat::from_fn(130, 11, |i, j| ((i * j) % 5) as f32 * 0.5 - 1.0);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..11 {
                let mut s = 0.0;
                for k in 0..130 {
                    s += a.at(i, k) * b.at(k, j);
                }
                assert!(approx(c.at(i, j), s, 1e-5), "({i},{j}): {} vs {s}", c.at(i, j));
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_fn(4, 6, |i, j| (i as f32 - j as f32) * 0.5);
        let v: Vec<f32> = (0..6).map(|i| i as f32 * 0.3 - 1.0).collect();
        let got = a.matvec(&v);
        let vm = Mat::from_vec(6, 1, v.clone());
        let want = a.matmul(&vm);
        for i in 0..4 {
            assert!(approx(got[i], want.at(i, 0), 1e-6));
        }
    }

    #[test]
    fn dot_handles_remainder_lengths() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
            let b: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 * 0.05).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(approx(dot(&a, &b), want, 1e-5), "n={n}");
        }
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn select_rows_and_vstack() {
        let a = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f32);
        let top = a.select_rows(&[0, 2]);
        let bot = a.select_rows(&[1, 3]);
        let all = top.vstack(&bot);
        assert_eq!(all.row(0), a.row(0));
        assert_eq!(all.row(1), a.row(2));
        assert_eq!(all.row(2), a.row(1));
        assert_eq!(all.row(3), a.row(3));
    }

    #[test]
    fn row_sq_norms() {
        let a = Mat::from_vec(2, 2, vec![3., 4., 0., 2.]);
        assert_eq!(a.row_sq_norms(), vec![25., 4.]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
