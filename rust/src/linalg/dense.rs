//! Row-major dense `f32` matrix with the operations the solver needs:
//! tiled multithreaded GEMM, transposed products, row views, and a few
//! vector primitives (`dot`, `axpy`, `axpy2`, `dot4`) shared with the CD
//! hot loop.
//!
//! The GEMM is the stage-1 compute backbone: output rows are partitioned
//! into contiguous bands over the persistent worker pool
//! ([`crate::util::threads::parallel_chunks`]), and each band runs a
//! KC×NC cache-tiled i-k-j loop whose inner microkernels (`axpy2`,
//! `dot4`) are written for FMA autovectorisation with AVX2 fast paths.
//! Banding only partitions rows, so every thread count produces
//! bit-identical results — the `threads == 1` case *is* the serial
//! reference path used by the differential property tests.

use crate::util::threads::parallel_chunks;
use std::ops::Range;

/// Depth (reduction) block: a KC-span of `B` rows stays hot in L1/L2
/// while a band's rows stream against it.
const GEMM_KC: usize = 256;
/// Column block: an NC-wide panel of `B`/`C` columns bounds the working
/// set when `n` is large.
const GEMM_NC: usize = 512;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self @ other` — serial entry point; identical to
    /// [`Mat::matmul_threads`] with one thread.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_threads(other, 1)
    }

    /// `self @ other` — cache-tiled i-k-j GEMM with output rows banded
    /// over `threads` workers. Row-major friendly: the microkernel is a
    /// fused two-row axpy over a contiguous NC-wide slice of the output
    /// row. Results are bit-identical for every thread count.
    pub fn matmul_threads(&self, other: &Mat, threads: usize) -> Mat {
        assert!(
            self.cols == other.rows,
            "matmul: lhs is {}x{} but rhs is {}x{} (lhs.cols must equal rhs.rows)",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        let (k, n) = (self.cols, other.cols);
        let mut out = Mat::zeros(self.rows, n);
        if k == 0 || n == 0 {
            return out;
        }
        parallel_chunks(&mut out.data, n, threads, |rows, band| {
            gemm_band(&self.data, &other.data, k, n, rows, band);
        });
        out
    }

    /// `self @ otherᵀ` — serial entry point; identical to
    /// [`Mat::matmul_nt_threads`] with one thread.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        self.matmul_nt_threads(other, 1)
    }

    /// `self @ otherᵀ` with output rows banded over `threads` workers.
    /// Both operands are row-major, so the kernel reads `other`'s rows
    /// directly — no transposed temporary is ever materialised — and
    /// amortises each lhs-row load over four rhs rows via [`dot4`].
    /// Used for Gram blocks and the serve scoring path.
    pub fn matmul_nt_threads(&self, other: &Mat, threads: usize) -> Mat {
        assert!(
            self.cols == other.cols,
            "matmul_nt: lhs is {}x{} but rhs is {}x{} (column counts must match)",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        let n = other.rows;
        let mut out = Mat::zeros(self.rows, n);
        if n == 0 {
            return out;
        }
        parallel_chunks(&mut out.data, n, threads, |rows, band| {
            for (bi, i) in rows.enumerate() {
                let arow = self.row(i);
                let crow = &mut band[bi * n..(bi + 1) * n];
                let mut j = 0usize;
                while j + 4 <= n {
                    let d = dot4(
                        arow,
                        other.row(j),
                        other.row(j + 1),
                        other.row(j + 2),
                        other.row(j + 3),
                    );
                    crow[j..j + 4].copy_from_slice(&d);
                    j += 4;
                }
                while j < n {
                    crow[j] = dot(arow, other.row(j));
                    j += 1;
                }
            }
        });
        out
    }

    /// `self @ v` for a vector `v` (len = cols).
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// Squared L2 norm of every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| dot(self.row(i), self.row(i))).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertically stack `self` on top of `other`.
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Maximum absolute entry-wise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// One output-row band of `C += A·B`: KC×NC cache tiling around a fused
/// two-row axpy microkernel. For any fixed element `C[i][j]` the k-updates
/// arrive in ascending order on the fixed KC grid regardless of how rows
/// were banded, which is what makes the parallel product bit-identical to
/// the serial one.
fn gemm_band(a: &[f32], b: &[f32], k: usize, n: usize, rows: Range<usize>, band: &mut [f32]) {
    for jc in (0..n).step_by(GEMM_NC) {
        let jw = GEMM_NC.min(n - jc);
        for kc in (0..k).step_by(GEMM_KC) {
            let kend = (kc + GEMM_KC).min(k);
            for (bi, i) in rows.clone().enumerate() {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut band[bi * n + jc..bi * n + jc + jw];
                let mut kk = kc;
                while kk + 2 <= kend {
                    let (a0, a1) = (arow[kk], arow[kk + 1]);
                    let b0 = &b[kk * n + jc..kk * n + jc + jw];
                    let b1 = &b[(kk + 1) * n + jc..(kk + 1) * n + jc + jw];
                    // Zero-skip mirrors the sparse-ish G rows the solver
                    // feeds through here; the branch choice depends only
                    // on A, never on the banding.
                    match (a0 != 0.0, a1 != 0.0) {
                        (true, true) => axpy2(a0, b0, a1, b1, crow),
                        (true, false) => axpy(a0, b0, crow),
                        (false, true) => axpy(a1, b1, crow),
                        (false, false) => {}
                    }
                    kk += 2;
                }
                if kk < kend {
                    let a0 = arow[kk];
                    if a0 != 0.0 {
                        axpy(a0, &b[kk * n + jc..kk * n + jc + jw], crow);
                    }
                }
            }
        }
    }
}

/// Contiguous dot product — the single hottest primitive in the whole
/// solver (called once per CD step with `len = B`). Dispatches to an
/// AVX2+FMA kernel when the CPU supports it (the x86-64 *baseline* target
/// only guarantees SSE2, so compile-time autovectorisation alone leaves
/// half the FLOPs on the table — see EXPERIMENTS.md §Perf iteration 3).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// Portable 8-lane accumulation: independent partial sums break the
/// sequential FP dependency chain and map onto SSE lanes.
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += ao[l] * bo[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 yes, 2 no
    // Relaxed on both sides: the cached value is an idempotent CPUID
    // fact, so racing initialisers all store the same byte and no other
    // data is published through this flag.
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma");
            // Relaxed: see above — any racing store writes the same value.
            STATE.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

/// AVX2+FMA dot: 4×8-lane accumulators (32 floats/iter) hide FMA latency.
///
/// # Safety
/// Caller must verify AVX2+FMA support (`avx2_available`) and pass
/// equal-length slices — the kernel reads `b` up to `a.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 8)),
            _mm256_loadu_ps(bp.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 16)),
            _mm256_loadu_ps(bp.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 24)),
            _mm256_loadu_ps(bp.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let mut s = hsum256(acc);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Horizontal sum of an 8-lane f32 vector.
///
/// # Safety
/// Caller must verify AVX2 support; pure register arithmetic otherwise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn hsum256(acc: core::arch::x86_64::__m256) -> f32 {
    use core::arch::x86_64::*;
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let sum4 = _mm_add_ps(hi, lo);
    let sum2 = _mm_add_ps(sum4, _mm_movehl_ps(sum4, sum4));
    let sum1 = _mm_add_ss(sum2, _mm_shuffle_ps(sum2, sum2, 0b01));
    _mm_cvtss_f32(sum1)
}

/// Four dot products sharing one pass over `a` — the matmul_nt
/// microkernel. Reusing the `a` load across four `b` rows quarters the
/// memory traffic on the lhs operand.
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    // Hard assert: the AVX2 path reads all four rows up to a.len(), so a
    // short slice from a caller would be an out-of-bounds read, not just a
    // wrong answer. One branch amortised over 4 dot products is free.
    assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len(),
        "dot4: slice lengths differ"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            return unsafe { dot4_avx2(a, b0, b1, b2, b3) };
        }
    }
    [
        dot_scalar(a, b0),
        dot_scalar(a, b1),
        dot_scalar(a, b2),
        dot_scalar(a, b3),
    ]
}

/// # Safety
/// Caller must verify AVX2+FMA support and that all four `b` rows are at
/// least `a.len()` long (asserted in `dot4`): each is read to `a.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4_avx2(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    use core::arch::x86_64::*;
    let n = a.len();
    let ap = a.as_ptr();
    let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
    let mut c0 = _mm256_setzero_ps();
    let mut c1 = _mm256_setzero_ps();
    let mut c2 = _mm256_setzero_ps();
    let mut c3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let av = _mm256_loadu_ps(ap.add(i));
        c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p0.add(i)), c0);
        c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p1.add(i)), c1);
        c2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p2.add(i)), c2);
        c3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(p3.add(i)), c3);
        i += 8;
    }
    let mut out = [hsum256(c0), hsum256(c1), hsum256(c2), hsum256(c3)];
    while i < n {
        let av = a[i];
        out[0] += av * b0[i];
        out[1] += av * b1[i];
        out[2] += av * b2[i];
        out[3] += av * b3[i];
        i += 1;
    }
    out
}

/// `y += a * x` over contiguous slices — the CD step's weight update.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            unsafe { axpy_avx2(a, x, y) };
            return;
        }
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// # Safety
/// Caller must verify AVX2+FMA support and `x.len() == y.len()` (the
/// debug assert in `axpy`): the kernel reads/writes both to `x.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = x.len();
    let av = _mm256_set1_ps(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 16 <= n {
        let y0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        let y1 = _mm256_fmadd_ps(
            av,
            _mm256_loadu_ps(xp.add(i + 8)),
            _mm256_loadu_ps(yp.add(i + 8)),
        );
        _mm256_storeu_ps(yp.add(i), y0);
        _mm256_storeu_ps(yp.add(i + 8), y1);
        i += 16;
    }
    while i + 8 <= n {
        let y0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        _mm256_storeu_ps(yp.add(i), y0);
        i += 8;
    }
    while i < n {
        *y.get_unchecked_mut(i) += a * x.get_unchecked(i);
        i += 1;
    }
}

/// `y += a0·x0 + a1·x1` — the fused two-row GEMM microkernel: one pass
/// over `y` retires two k-steps, halving output-row traffic versus two
/// `axpy` calls.
#[inline]
pub fn axpy2(a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
    // Hard assert: the AVX2 path reads both x rows up to y.len() (see
    // `dot4` for the rationale).
    assert!(
        x0.len() == y.len() && x1.len() == y.len(),
        "axpy2: slice lengths differ"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: feature presence checked above.
            unsafe { axpy2_avx2(a0, x0, a1, x1, y) };
            return;
        }
    }
    for ((yi, xi0), xi1) in y.iter_mut().zip(x0).zip(x1) {
        *yi += a0 * xi0;
        *yi += a1 * xi1;
    }
}

/// # Safety
/// Caller must verify AVX2+FMA support and that both `x` rows are at
/// least `y.len()` long (asserted in `axpy2`): each is read to `y.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy2_avx2(a0: f32, x0: &[f32], a1: f32, x1: &[f32], y: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = y.len();
    let av0 = _mm256_set1_ps(a0);
    let av1 = _mm256_set1_ps(a1);
    let x0p = x0.as_ptr();
    let x1p = x1.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 16 <= n {
        let mut y0 = _mm256_loadu_ps(yp.add(i));
        let mut y1 = _mm256_loadu_ps(yp.add(i + 8));
        y0 = _mm256_fmadd_ps(av0, _mm256_loadu_ps(x0p.add(i)), y0);
        y1 = _mm256_fmadd_ps(av0, _mm256_loadu_ps(x0p.add(i + 8)), y1);
        y0 = _mm256_fmadd_ps(av1, _mm256_loadu_ps(x1p.add(i)), y0);
        y1 = _mm256_fmadd_ps(av1, _mm256_loadu_ps(x1p.add(i + 8)), y1);
        _mm256_storeu_ps(yp.add(i), y0);
        _mm256_storeu_ps(yp.add(i + 8), y1);
        i += 16;
    }
    while i + 8 <= n {
        let mut y0 = _mm256_loadu_ps(yp.add(i));
        y0 = _mm256_fmadd_ps(av0, _mm256_loadu_ps(x0p.add(i)), y0);
        y0 = _mm256_fmadd_ps(av1, _mm256_loadu_ps(x1p.add(i)), y0);
        _mm256_storeu_ps(yp.add(i), y0);
        i += 8;
    }
    while i < n {
        let v = *y.get_unchecked(i) + a0 * *x0.get_unchecked(i) + a1 * *x1.get_unchecked(i);
        *y.get_unchecked_mut(i) = v;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let i4 = Mat::eye(4);
        assert_eq!(a.matmul(&i4), a);
        assert_eq!(i4.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_matches_matmul() {
        let a = Mat::from_fn(5, 7, |i, j| ((i * 13 + j * 7) % 5) as f32 - 2.0);
        let b = Mat::from_fn(6, 7, |i, j| ((i * 3 + j) % 4) as f32 - 1.5);
        let via_t = a.matmul(&b.transpose());
        let direct = a.matmul_nt(&b);
        assert!(via_t.max_abs_diff(&direct) < 1e-5);
    }

    #[test]
    fn matmul_blocked_matches_naive_large() {
        // Exercise the BK blocking boundary (k > 64).
        let a = Mat::from_fn(9, 130, |i, j| ((i + j) % 7) as f32 * 0.25 - 0.5);
        let b = Mat::from_fn(130, 11, |i, j| ((i * j) % 5) as f32 * 0.5 - 1.0);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..11 {
                let mut s = 0.0;
                for k in 0..130 {
                    s += a.at(i, k) * b.at(k, j);
                }
                assert!(approx(c.at(i, j), s, 1e-5), "({i},{j}): {} vs {s}", c.at(i, j));
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_fn(4, 6, |i, j| (i as f32 - j as f32) * 0.5);
        let v: Vec<f32> = (0..6).map(|i| i as f32 * 0.3 - 1.0).collect();
        let got = a.matvec(&v);
        let vm = Mat::from_vec(6, 1, v.clone());
        let want = a.matmul(&vm);
        for i in 0..4 {
            assert!(approx(got[i], want.at(i, 0), 1e-6));
        }
    }

    #[test]
    fn dot_handles_remainder_lengths() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
            let b: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 * 0.05).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(approx(dot(&a, &b), want, 1e-5), "n={n}");
        }
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn select_rows_and_vstack() {
        let a = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f32);
        let top = a.select_rows(&[0, 2]);
        let bot = a.select_rows(&[1, 3]);
        let all = top.vstack(&bot);
        assert_eq!(all.row(0), a.row(0));
        assert_eq!(all.row(1), a.row(2));
        assert_eq!(all.row(2), a.row(1));
        assert_eq!(all.row(3), a.row(3));
    }

    #[test]
    fn row_sq_norms() {
        let a = Mat::from_vec(2, 2, vec![3., 4., 0., 2.]);
        assert_eq!(a.row_sq_norms(), vec![25., 4.]);
    }

    #[test]
    #[should_panic(expected = "lhs.cols must equal rhs.rows")]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    #[should_panic(expected = "column counts must match")]
    fn matmul_nt_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul_nt(&b);
    }

    #[test]
    fn axpy2_matches_two_axpys() {
        for n in [0usize, 1, 7, 8, 15, 16, 17, 33] {
            let x0: Vec<f32> = (0..n).map(|i| i as f32 * 0.3 - 1.0).collect();
            let x1: Vec<f32> = (0..n).map(|i| 0.5 - i as f32 * 0.2).collect();
            let mut y = vec![0.25f32; n];
            let mut want = y.clone();
            axpy2(1.5, &x0, -0.75, &x1, &mut y);
            axpy(1.5, &x0, &mut want);
            axpy(-0.75, &x1, &mut want);
            for i in 0..n {
                assert!((y[i] - want[i]).abs() < 1e-5, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn dot4_matches_four_dots() {
        for n in [0usize, 3, 8, 9, 31, 64] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
            let bs: Vec<Vec<f32>> = (0..4)
                .map(|r| (0..n).map(|i| ((i + r) as f32 * 0.3).cos()).collect())
                .collect();
            let got = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for r in 0..4 {
                let want = dot(&a, &bs[r]);
                assert!((got[r] - want).abs() < 1e-4, "n={n} r={r}");
            }
        }
    }

    #[test]
    fn matmul_threads_bitwise_matches_serial() {
        // Shapes straddle the KC (256) and NC (512) tile boundaries and
        // the axpy2 pairing, so every code path in the band kernel runs.
        for (m, k, n) in [(5usize, 3usize, 4usize), (9, 257, 17), (3, 64, 513), (1, 1, 1)] {
            let a = Mat::from_fn(m, k, |i, j| ((i * 31 + j * 7) % 11) as f32 * 0.25 - 1.0);
            let b = Mat::from_fn(k, n, |i, j| ((i * 13 + j * 3) % 7) as f32 * 0.5 - 1.5);
            let serial = a.matmul_threads(&b, 1);
            for t in [2usize, 3, 8] {
                let par = a.matmul_threads(&b, t);
                assert_eq!(serial, par, "m={m} k={k} n={n} t={t}");
            }
        }
    }

    #[test]
    fn matmul_nt_threads_bitwise_matches_serial() {
        let a = Mat::from_fn(7, 33, |i, j| ((i * 5 + j) % 9) as f32 * 0.3 - 1.2);
        let b = Mat::from_fn(13, 33, |i, j| ((i + j * 11) % 6) as f32 * 0.4 - 1.0);
        let serial = a.matmul_nt_threads(&b, 1);
        for t in [2usize, 3, 8] {
            assert_eq!(serial, a.matmul_nt_threads(&b, t), "t={t}");
        }
        // And it agrees with the transpose formulation.
        assert!(serial.max_abs_diff(&a.matmul(&b.transpose())) < 1e-4);
    }

    #[test]
    fn matmul_zero_dims_are_empty() {
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
        let d = Mat::zeros(2, 5).matmul(&Mat::zeros(5, 0));
        assert_eq!((d.rows, d.cols), (2, 0));
    }
}
