//! Cholesky factorisation.
//!
//! The paper (§4, footnote 3) considers Cholesky "an attractive alternative
//! at first glance" for factoring `K_BB` but rejects it because kernel
//! matrices are often only *semi*-definite and Cholesky requires strict
//! positive definiteness. We implement it anyway: (a) tests demonstrate the
//! failure mode the paper describes, (b) the shifted variant is a useful
//! cross-check for the Jacobi eigensolver, and (c) downstream users may
//! want it for well-conditioned kernels.

use crate::linalg::Mat;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
/// Returns `Err` with the failing pivot index if `A` is not (numerically)
/// strictly positive definite — exactly the breakdown the paper warns about.
pub fn cholesky(a: &Mat) -> Result<Mat, usize> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for j in 0..n {
        let mut d = a.at(j, j) as f64;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(j);
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in (j + 1)..n {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    Ok(Mat::from_vec(
        n,
        n,
        l.into_iter().map(|x| x as f32).collect(),
    ))
}

/// Solve `L y = b` (forward substitution) for lower-triangular `L`.
pub fn forward_subst(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * y[k];
        }
        y[i] = s / l.at(i, i) as f64;
    }
    y.into_iter().map(|x| x as f32).collect()
}

/// Solve `Lᵀ x = y` (backward substitution).
pub fn backward_subst_t(l: &Mat, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= l.at(k, i) as f64 * x[k];
        }
        x[i] = s / l.at(i, i) as f64;
    }
    x.into_iter().map(|x| x as f32).collect()
}

/// Solve `A x = b` given the Cholesky factor of `A`.
pub fn chol_solve(l: &Mat, b: &[f32]) -> Vec<f32> {
    backward_subst_t(l, &forward_subst(l, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64, jitter: f32) -> Mat {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, n + 2, |_, _| rng.normal() as f32);
        let mut a = x.matmul_nt(&x);
        for i in 0..n {
            let v = a.at(i, i) + jitter;
            a.set(i, i, v);
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(12, 3, 0.5);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul_nt(&l);
        assert!(a.max_abs_diff(&llt) < 1e-3, "{}", a.max_abs_diff(&llt));
    }

    #[test]
    fn solve_matches() {
        let a = random_spd(8, 9, 1.0);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(1);
        let x_true: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let b = a.matvec(&x_true);
        let x = chol_solve(&l, &b);
        for i in 0..8 {
            assert!((x[i] - x_true[i]).abs() < 1e-3, "{} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn fails_on_semidefinite_matrix() {
        // Rank-1 PSD matrix — the paper's footnote-3 failure mode: Cholesky
        // breaks down on a semi-definite kernel matrix.
        let v = Mat::from_vec(3, 1, vec![1., 2., 3.]);
        let a = v.matmul_nt(&v);
        let r = cholesky(&a);
        assert!(r.is_err(), "expected breakdown on semidefinite input");
        // ... while the Jacobi eigensolver handles it fine:
        let e = crate::linalg::eigen::sym_eig(&a, 40, 1e-13);
        assert_eq!(e.effective_rank(1e-6), 1);
        assert!((e.values[0] - 14.0).abs() < 1e-4);
    }

    #[test]
    fn fails_on_indefinite_matrix() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_structure() {
        let a = random_spd(6, 5, 0.5);
        let l = cholesky(&a).unwrap();
        for i in 0..6 {
            for j in (i + 1)..6 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }
}
