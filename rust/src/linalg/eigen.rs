//! Symmetric eigendecomposition via the cyclic Jacobi method — serial
//! ([`sym_eig`]) and pool-parallel ([`sym_eig_threads`]).
//!
//! The paper (§4, footnote 3) rejects Cholesky for the landmark matrix
//! `K_BB` because kernel matrices are routinely *near*-singular and
//! Cholesky needs strict positive definiteness; it uses an eigensolver
//! (cuSOLVER `syevd` on GPU) and then drops eigenvalues below
//! `ε·λ_max`. Our substitute is cyclic Jacobi in `f64`: O(B³) per sweep,
//! unconditionally stable on symmetric matrices, and accurate for the small
//! eigenvalues we must threshold. At small landmark budgets it is never the
//! bottleneck, but at large B the paper's "preparation" stage (its Fig. 3
//! breakdown) becomes eigh-bound — [`sym_eig_threads`] parallelises the
//! sweeps over the persistent worker pool using the classic round-robin
//! tournament ordering (Brent–Luk): each round rotates a set of *disjoint*
//! `(p, q)` pairs, so rotation parameters are computed from one snapshot
//! and the row/column updates write non-overlapping data. Values depend
//! only on the round structure, never on which worker runs an update, so
//! the result is deterministic for any fixed thread count (in fact
//! bit-identical across thread counts).

use crate::linalg::Mat;
use crate::util::threads::parallel_for_each;

/// Result of a symmetric eigendecomposition: `A = V diag(λ) Vᵀ`,
/// eigenvalues sorted in DESCENDING order, `V` column-orthonormal
/// (stored row-major: `vectors.at(i, k)` is component `i` of eigenvector `k`).
#[derive(Clone, Debug)]
pub struct SymEig {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// One Jacobi rotation: zero `A[p][q]` with the Givens pair `(c, s)`.
#[derive(Clone, Copy)]
struct Rotation {
    p: usize,
    q: usize,
    c: f64,
    s: f64,
}

/// Stable rotation parameters for the pivot `(p, q)`
/// (Golub & Van Loan 8.4).
#[inline]
fn rotation(app: f64, aqq: f64, apq: f64) -> (f64, f64) {
    let theta = (aqq - app) / (2.0 * apq);
    let t = if theta >= 0.0 {
        1.0 / (theta + (1.0 + theta * theta).sqrt())
    } else {
        -1.0 / (-theta + (1.0 + theta * theta).sqrt())
    };
    let c = 1.0 / (1.0 + t * t).sqrt();
    (c, t * c)
}

/// Cyclic Jacobi eigensolver for a symmetric matrix given as `Mat` (f32
/// storage, f64 compute). `max_sweeps` bounds the work; convergence is
/// declared when the off-diagonal Frobenius norm falls below
/// `tol * ||A||_F`.
pub fn sym_eig(a: &Mat, max_sweeps: usize, tol: f64) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    // Work in f64 for accuracy near machine-epsilon thresholds.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let thresh = off_threshold(&m, tol);

    for _sweep in 0..max_sweeps {
        if off_norm(&m, n) <= thresh {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() <= thresh / (n as f64) {
                    continue;
                }
                let (c, s) = rotation(m[p * n + p], m[q * n + q], apq);
                // Apply rotation to rows/cols p and q of A.
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    extract(&m, &v, n)
}

/// Below this dimension [`sym_eig_threads`] runs the serial cyclic path:
/// a tournament round's phase slot is only O(n) multiply-adds, so for
/// small matrices the per-round pool dispatches would cost more than the
/// rotations themselves. The cutover depends only on `n` — never on
/// `threads` — so a stage-1 factor stays bit-identical across thread
/// counts on either side of it.
const TOURNAMENT_MIN_DIM: usize = 128;

/// Pool-parallel eigensolver: round-robin tournament Jacobi
/// ([`sym_eig_tournament`]) for matrices of at least
/// `TOURNAMENT_MIN_DIM` (128) rows — the eigh-bound "preparation" regime at
/// large landmark budgets — and the serial cyclic path below that, where
/// pool dispatch overhead would dominate the O(n) phase slots. The
/// cutover depends only on the matrix size, so the result is
/// deterministic for every fixed thread count (bit-identical across
/// thread counts, in fact).
pub fn sym_eig_threads(a: &Mat, max_sweeps: usize, tol: f64, threads: usize) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let mut span = crate::obs::Span::new("eigensolve");
    span.arg("n", a.rows as f64);
    span.arg("threads", threads as f64);
    if a.rows < TOURNAMENT_MIN_DIM {
        sym_eig(a, max_sweeps, tol)
    } else {
        sym_eig_tournament(a, max_sweeps, tol, threads)
    }
}

/// Cyclic Jacobi with round-robin tournament ordering, parallelised over
/// the persistent pool (no size cutover — [`sym_eig_threads`] adds that).
///
/// Each sweep visits every `(p, q)` pair exactly once, grouped into
/// rounds of mutually disjoint pairs (the circle method used for
/// round-robin tournaments). Per round: rotation parameters for all
/// pairs are computed from the round-start snapshot, then two barrier
/// phases apply the column updates (`A ← A·Q` and `V ← V·Q`) and the row
/// updates (`A ← Qᵀ·A`) in parallel over the pairs — each pair owns its
/// two columns (resp. rows), so writes are disjoint and the result does
/// not depend on scheduling. Convergence criterion, pivot threshold and
/// rotation formulas match [`sym_eig`]; the two orderings agree on the
/// decomposition up to the usual Jacobi accuracy (the same `tol`-driven
/// off-diagonal bound), not bit for bit.
///
/// `threads` caps the pool fan-out (1 runs the rounds inline). The
/// output is deterministic for every fixed thread count.
pub fn sym_eig_tournament(a: &Mat, max_sweeps: usize, tol: f64, threads: usize) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    if n <= 2 {
        // 0, 1 or a single pair: the tournament degenerates to the cyclic
        // order; run the serial path.
        return sym_eig(a, max_sweeps, tol);
    }
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let thresh = off_threshold(&m, tol);

    // Tournament over `players` seats (n padded to even with a phantom).
    let players = n + (n % 2);
    let rounds = players - 1;
    let mut rots: Vec<Rotation> = Vec::with_capacity(players / 2);
    for _sweep in 0..max_sweeps {
        if off_norm(&m, n) <= thresh {
            break;
        }
        for r in 0..rounds {
            rots.clear();
            for (p, q) in round_pairs(players, r) {
                if p >= n || q >= n {
                    continue; // phantom seat (odd n sits one index out)
                }
                let apq = m[p * n + q];
                if apq.abs() <= thresh / (n as f64) {
                    continue;
                }
                let (c, s) = rotation(m[p * n + p], m[q * n + q], apq);
                rots.push(Rotation { p, q, c, s });
            }
            if !rots.is_empty() {
                apply_round(&mut m, &mut v, n, &rots, threads);
            }
        }
    }

    extract(&m, &v, n)
}

/// Pairs of round `r` in a `players`-seat round-robin tournament
/// (`players` even): seat `players−1` is fixed, the rest rotate. Every
/// pair of seats meets exactly once across `players − 1` rounds, and the
/// pairs within one round are mutually disjoint.
fn round_pairs(players: usize, r: usize) -> Vec<(usize, usize)> {
    let wheel = players - 1;
    let mut pairs = Vec::with_capacity(players / 2);
    let a = r % wheel;
    pairs.push((a.min(players - 1), a.max(players - 1)));
    for i in 1..players / 2 {
        let x = (r + i) % wheel;
        let y = (r + wheel - i) % wheel;
        pairs.push((x.min(y), x.max(y)));
    }
    pairs
}

/// Shared mutable base pointer for the disjoint rotation updates.
#[derive(Clone, Copy)]
struct MatPtr(*mut f64);
// SAFETY: every parallel phase writes only the rows or columns owned by
// its (disjoint) pair — see `apply_round`.
unsafe impl Send for MatPtr {}
unsafe impl Sync for MatPtr {}

/// Apply one round of disjoint rotations: `A ← Qᵀ·A·Q`, `V ← V·Q` where
/// `Q` is the product of the round's (commuting) Givens rotations. Two
/// barrier phases keep reads and writes disjoint: the first does all
/// column updates (`A·Q` for slots below `rots.len()`, `V·Q` above —
/// `V`'s update only needs the rotation parameters, so it shares the
/// column phase instead of paying a third dispatch), the second does the
/// row updates; each pair owns its two columns (resp. rows).
fn apply_round(m: &mut [f64], v: &mut [f64], n: usize, rots: &[Rotation], threads: usize) {
    let mp = MatPtr(m.as_mut_ptr());
    let vp = MatPtr(v.as_mut_ptr());
    // Phase 1: A ← A·Q and V ← V·Q (disjoint column pairs of either
    // matrix — 2·rots.len() independent slots).
    parallel_for_each(2 * rots.len(), threads, |slot| {
        let Rotation { p, q, c, s } = rots[slot % rots.len()];
        let base = if slot < rots.len() { mp.0 } else { vp.0 };
        for k in 0..n {
            // SAFETY: this job reads and writes only columns p and q of
            // its own matrix, which no other slot in the phase touches;
            // the barrier between phases orders cross-pair visibility.
            unsafe {
                let akp = *base.add(k * n + p);
                let akq = *base.add(k * n + q);
                *base.add(k * n + p) = c * akp - s * akq;
                *base.add(k * n + q) = s * akp + c * akq;
            }
        }
    });
    // Phase 2: A ← Qᵀ·A (disjoint row pairs).
    parallel_for_each(rots.len(), threads, |ri| {
        let Rotation { p, q, c, s } = rots[ri];
        let base = mp.0;
        for k in 0..n {
            // SAFETY: rows p and q belong to this pair alone.
            unsafe {
                let apk = *base.add(p * n + k);
                let aqk = *base.add(q * n + k);
                *base.add(p * n + k) = c * apk - s * aqk;
                *base.add(q * n + k) = s * apk + c * aqk;
            }
        }
    });
}

/// Convergence threshold `tol · ||A||_F` (floored away from zero).
fn off_threshold(m: &[f64], tol: f64) -> f64 {
    let fro: f64 = m.iter().map(|x| x * x).sum::<f64>().sqrt();
    tol * fro.max(f64::MIN_POSITIVE)
}

/// Frobenius norm of the strict upper triangle, mirrored (`√(2·Σ a²_pq)`).
fn off_norm(m: &[f64], n: usize) -> f64 {
    let mut off = 0.0f64;
    for p in 0..n {
        for q in (p + 1)..n {
            off += m[p * n + q] * m[p * n + q];
        }
    }
    (2.0 * off).sqrt()
}

/// Extract the diagonal, sort descending, permute eigenvector columns.
fn extract(m: &[f64], v: &[f64], n: usize) -> SymEig {
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    // `total_cmp`, not `partial_cmp(..).unwrap()`: an Inf-contaminated
    // Gram matrix turns the diagonal into NaNs, and sorting must degrade
    // to a deterministic (garbage-valued) decomposition instead of
    // panicking mid-training.
    order.sort_by(|&i, &j| diag[j].total_cmp(&diag[i]));
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newk, &oldk) in order.iter().enumerate() {
        for i in 0..n {
            vectors.data[i * n + newk] = v[i * n + oldk] as f32;
        }
    }
    SymEig { values, vectors }
}

impl SymEig {
    /// Number of eigenvalues kept when dropping those below
    /// `eps * λ_max` (the paper's adaptive rank truncation). Non-positive
    /// eigenvalues are always dropped.
    pub fn effective_rank(&self, eps: f64) -> usize {
        let lmax = self.values.first().copied().unwrap_or(0.0);
        if lmax <= 0.0 {
            return 0;
        }
        self.values
            .iter()
            .take_while(|&&l| l > eps * lmax && l > 0.0)
            .count()
    }

    /// Whitening map `W = V_r Λ_r^{-1/2}` (n×r) such that
    /// `(K_nB W)(K_nB W)ᵀ ≈ K_nB K_BB⁺ K_Bn` — the Nyström factor map.
    ///
    /// The rank is clamped to the *positive* spectrum: a non-positive
    /// eigenvalue has no real inverse square root, and the old clamp to
    /// `f64::MIN_POSITIVE` manufactured a ~1e154 column scale that
    /// poisoned the whole factor on indefinite (noise-perturbed) inputs.
    /// Columns with `λ ≤ 0` are dropped instead, so the returned matrix
    /// may have fewer than `rank` columns.
    pub fn whitening_map(&self, rank: usize) -> Mat {
        let n = self.vectors.rows;
        let r = self
            .values
            .iter()
            .take(rank.min(self.values.len()))
            .take_while(|&&l| l > 0.0)
            .count();
        let mut w = Mat::zeros(n, r);
        for k in 0..r {
            let scale = 1.0 / self.values[k].sqrt();
            for i in 0..n {
                w.data[i * r + k] = (self.vectors.at(i, k) as f64 * scale) as f32;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal() as f32;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    fn reconstruct(e: &SymEig) -> Mat {
        let n = e.vectors.rows;
        Mat::from_fn(n, n, |i, j| {
            (0..n)
                .map(|k| {
                    e.vectors.at(i, k) as f64 * e.values[k] * e.vectors.at(j, k) as f64
                })
                .sum::<f64>() as f32
        })
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let e = sym_eig(&a, 30, 1e-12);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 2.0).abs() < 1e-9);
        assert!((e.values[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let e = sym_eig(&a, 30, 1e-14);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random() {
        let a = random_symmetric(24, 7);
        let e = sym_eig(&a, 50, 1e-13);
        let r = reconstruct(&e);
        assert!(a.max_abs_diff(&r) < 1e-4, "diff {}", a.max_abs_diff(&r));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(16, 3);
        let e = sym_eig(&a, 50, 1e-13);
        let vt_v = e.vectors.transpose().matmul(&e.vectors);
        assert!(vt_v.max_abs_diff(&Mat::eye(16)) < 1e-5);
    }

    #[test]
    fn eigen_equation_holds() {
        let a = random_symmetric(12, 11);
        let e = sym_eig(&a, 50, 1e-13);
        for k in 0..12 {
            let v: Vec<f32> = (0..12).map(|i| e.vectors.at(i, k)).collect();
            let av = a.matvec(&v);
            for i in 0..12 {
                let want = e.values[k] as f32 * v[i];
                assert!(
                    (av[i] - want).abs() < 1e-4,
                    "k={k} i={i}: {} vs {want}",
                    av[i]
                );
            }
        }
    }

    #[test]
    fn psd_gram_matrix_nonnegative_spectrum() {
        // Gram matrix of random vectors is PSD: eigenvalues >= -tiny.
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(10, 4, |_, _| rng.normal() as f32);
        let g = x.matmul_nt(&x);
        let e = sym_eig(&g, 50, 1e-13);
        assert!(e.values.iter().all(|&l| l > -1e-5), "{:?}", e.values);
        // Rank <= 4: at most 4 eigenvalues significantly above zero.
        assert_eq!(e.effective_rank(1e-6), 4);
    }

    #[test]
    fn effective_rank_thresholding() {
        let a = Mat::from_vec(3, 3, vec![1., 0., 0., 0., 1e-3, 0., 0., 0., 1e-9]);
        let e = sym_eig(&a, 30, 1e-14);
        assert_eq!(e.effective_rank(1e-6), 2);
        assert_eq!(e.effective_rank(1e-12), 3);
        assert_eq!(e.effective_rank(0.5), 1);
    }

    #[test]
    fn whitening_map_whitens() {
        // W = V Λ^{-1/2}  =>  Wᵀ A W = I on the kept subspace.
        let a = random_symmetric(8, 13);
        // Make PSD: A := AᵀA (via matmul with transpose).
        let a = a.transpose().matmul(&a);
        let e = sym_eig(&a, 60, 1e-13);
        let r = e.effective_rank(1e-10);
        let w = e.whitening_map(r);
        let wtaw = w.transpose().matmul(&a.matmul(&w));
        assert!(wtaw.max_abs_diff(&Mat::eye(r)) < 1e-3);
    }

    #[test]
    fn one_by_one() {
        let a = Mat::from_vec(1, 1, vec![4.0]);
        let e = sym_eig(&a, 10, 1e-14);
        assert_eq!(e.values.len(), 1);
        assert!((e.values[0] - 4.0).abs() < 1e-12);
    }

    // --- regression: NaN-contaminated input must not panic the sort ---

    #[test]
    fn nan_contaminated_input_does_not_panic() {
        // An Inf entry turns rotations into NaNs; the eigenvalue sort
        // previously hit `partial_cmp(..).unwrap()` and panicked.
        let a = Mat::from_vec(
            3,
            3,
            vec![f32::INFINITY, 1.0, 0.0, 1.0, 2.0, 0.5, 0.0, 0.5, -1.0],
        );
        let e = sym_eig(&a, 30, 1e-12);
        assert_eq!(e.values.len(), 3);
        let ep = sym_eig_tournament(&a, 30, 1e-12, 4);
        assert_eq!(ep.values.len(), 3);
        // Degenerate results are garbage but deterministic; rank 0 so no
        // downstream stage consumes the NaNs.
        let nan = Mat::from_vec(2, 2, vec![f32::NAN, 0.0, 0.0, 1.0]);
        let en = sym_eig(&nan, 30, 1e-12);
        assert_eq!(en.values.len(), 2);
    }

    // --- regression: indefinite spectra must not poison the whitening ---

    #[test]
    fn whitening_map_drops_nonpositive_eigenvalues() {
        // Indefinite "Gram" matrix (noise pushed one eigenvalue negative):
        // the old clamp to f64::MIN_POSITIVE emitted a ~1e154 column.
        let e = SymEig {
            values: vec![4.0, 0.0, -1.0],
            vectors: Mat::eye(3),
        };
        let w = e.whitening_map(3);
        assert_eq!(w.rows, 3);
        assert_eq!(w.cols, 1, "non-positive eigenvalues must be dropped");
        assert!((w.at(0, 0) - 0.5).abs() < 1e-6);
        assert!(w.data.iter().all(|x| x.is_finite() && x.abs() < 1e3));
        // An all-non-positive spectrum yields an empty map, not a huge one.
        let e0 = SymEig {
            values: vec![-2.0, -3.0],
            vectors: Mat::eye(2),
        };
        assert_eq!(e0.whitening_map(2).cols, 0);
    }

    // --- parallel tournament Jacobi ---

    #[test]
    fn round_pairs_cover_every_pair_once_disjointly() {
        for players in [4usize, 6, 8, 14] {
            let mut seen = std::collections::HashSet::new();
            for r in 0..players - 1 {
                let pairs = round_pairs(players, r);
                assert_eq!(pairs.len(), players / 2, "round {r}");
                let mut used = vec![false; players];
                for &(p, q) in &pairs {
                    assert!(p < q, "round {r}: pair ({p},{q}) not ordered");
                    assert!(!used[p] && !used[q], "round {r}: seat reused");
                    used[p] = true;
                    used[q] = true;
                    assert!(seen.insert((p, q)), "pair ({p},{q}) repeated");
                }
            }
            assert_eq!(seen.len(), players * (players - 1) / 2);
        }
    }

    #[test]
    fn tournament_matches_serial_accuracy() {
        // Same suite, same tolerances as the serial tests above.
        let a = random_symmetric(24, 7);
        let e = sym_eig_tournament(&a, 50, 1e-13, 4);
        let r = reconstruct(&e);
        assert!(a.max_abs_diff(&r) < 1e-4, "diff {}", a.max_abs_diff(&r));
        let vt_v = e.vectors.transpose().matmul(&e.vectors);
        assert!(vt_v.max_abs_diff(&Mat::eye(24)) < 1e-5);
        // Eigenvalues agree with the serial ordering's.
        let es = sym_eig(&a, 50, 1e-13);
        for (l_par, l_ser) in e.values.iter().zip(&es.values) {
            assert!((l_par - l_ser).abs() < 1e-6, "{l_par} vs {l_ser}");
        }
    }

    #[test]
    fn tournament_eigen_equation_holds() {
        let a = random_symmetric(13, 11); // odd n exercises the phantom seat
        let e = sym_eig_tournament(&a, 50, 1e-13, 3);
        for k in 0..13 {
            let v: Vec<f32> = (0..13).map(|i| e.vectors.at(i, k)).collect();
            let av = a.matvec(&v);
            for i in 0..13 {
                let want = e.values[k] as f32 * v[i];
                assert!(
                    (av[i] - want).abs() < 1e-4,
                    "k={k} i={i}: {} vs {want}",
                    av[i]
                );
            }
        }
    }

    #[test]
    fn tournament_deterministic_per_thread_count() {
        let a = random_symmetric(18, 29);
        let reference = sym_eig_tournament(&a, 50, 1e-13, 1);
        for t in [1usize, 2, 3, 8] {
            for _rep in 0..2 {
                let e = sym_eig_tournament(&a, 50, 1e-13, t);
                assert_eq!(e.values, reference.values, "values differ at t={t}");
                assert_eq!(
                    e.vectors, reference.vectors,
                    "vectors differ at t={t}"
                );
            }
        }
    }

    #[test]
    fn threads_entry_point_cuts_over_on_size_only() {
        // Below the cutover: identical to the serial cyclic path for
        // every thread count (dispatch overhead would dominate there).
        for n in [1usize, 2, 24, TOURNAMENT_MIN_DIM - 1] {
            let a = random_symmetric(n, 41);
            let s = sym_eig(&a, 30, 1e-13);
            for t in [1usize, 4] {
                let e = sym_eig_threads(&a, 30, 1e-13, t);
                assert_eq!(e.values, s.values, "n={n} t={t}");
                assert_eq!(e.vectors, s.vectors, "n={n} t={t}");
            }
        }
        // At the cutover: identical to the tournament path, again for
        // every thread count (the switch depends only on n).
        let a = random_symmetric(TOURNAMENT_MIN_DIM, 43);
        let tour = sym_eig_tournament(&a, 40, 1e-12, 1);
        for t in [1usize, 4] {
            let e = sym_eig_threads(&a, 40, 1e-12, t);
            assert_eq!(e.values, tour.values, "t={t}");
            assert_eq!(e.vectors, tour.vectors, "t={t}");
        }
    }
}
