//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! The paper (§4, footnote 3) rejects Cholesky for the landmark matrix
//! `K_BB` because kernel matrices are routinely *near*-singular and
//! Cholesky needs strict positive definiteness; it uses an eigensolver
//! (cuSOLVER `syevd` on GPU) and then drops eigenvalues below
//! `ε·λ_max`. Our substitute is cyclic Jacobi in `f64`: O(B³) per sweep,
//! unconditionally stable on symmetric matrices, and accurate for the small
//! eigenvalues we must threshold. It runs once per kernel parameter, on a
//! B×B matrix, so it is never the bottleneck (matching the paper's own
//! breakdown where eigh is part of "preparation").

use crate::linalg::Mat;

/// Result of a symmetric eigendecomposition: `A = V diag(λ) Vᵀ`,
/// eigenvalues sorted in DESCENDING order, `V` column-orthonormal
/// (stored row-major: `vectors.at(i, k)` is component `i` of eigenvector `k`).
#[derive(Clone, Debug)]
pub struct SymEig {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Cyclic Jacobi eigensolver for a symmetric matrix given as `Mat` (f32
/// storage, f64 compute). `max_sweeps` bounds the work; convergence is
/// declared when the off-diagonal Frobenius norm falls below
/// `tol * ||A||_F`.
pub fn sym_eig(a: &Mat, max_sweeps: usize, tol: f64) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    // Work in f64 for accuracy near machine-epsilon thresholds.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let fro: f64 = m.iter().map(|x| x * x).sum::<f64>().sqrt();
    let thresh = tol * fro.max(f64::MIN_POSITIVE);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if (2.0 * off).sqrt() <= thresh {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() <= thresh / (n as f64) {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                // Stable rotation computation (Golub & Van Loan 8.4).
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and q of A.
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract diagonal, sort descending, permute eigenvector columns.
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newk, &oldk) in order.iter().enumerate() {
        for i in 0..n {
            vectors.data[i * n + newk] = v[i * n + oldk] as f32;
        }
    }
    SymEig { values, vectors }
}

impl SymEig {
    /// Number of eigenvalues kept when dropping those below
    /// `eps * λ_max` (the paper's adaptive rank truncation). Non-positive
    /// eigenvalues are always dropped.
    pub fn effective_rank(&self, eps: f64) -> usize {
        let lmax = self.values.first().copied().unwrap_or(0.0);
        if lmax <= 0.0 {
            return 0;
        }
        self.values
            .iter()
            .take_while(|&&l| l > eps * lmax && l > 0.0)
            .count()
    }

    /// Whitening map `W = V_r Λ_r^{-1/2}` (n×r) such that
    /// `(K_nB W)(K_nB W)ᵀ ≈ K_nB K_BB⁺ K_Bn` — the Nyström factor map.
    pub fn whitening_map(&self, rank: usize) -> Mat {
        let n = self.vectors.rows;
        let r = rank.min(self.values.len());
        let mut w = Mat::zeros(n, r);
        for k in 0..r {
            let scale = 1.0 / self.values[k].max(f64::MIN_POSITIVE).sqrt();
            for i in 0..n {
                w.data[i * r + k] = (self.vectors.at(i, k) as f64 * scale) as f32;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal() as f32;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    fn reconstruct(e: &SymEig) -> Mat {
        let n = e.vectors.rows;
        Mat::from_fn(n, n, |i, j| {
            (0..n)
                .map(|k| {
                    e.vectors.at(i, k) as f64 * e.values[k] * e.vectors.at(j, k) as f64
                })
                .sum::<f64>() as f32
        })
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let e = sym_eig(&a, 30, 1e-12);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 2.0).abs() < 1e-9);
        assert!((e.values[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let e = sym_eig(&a, 30, 1e-14);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random() {
        let a = random_symmetric(24, 7);
        let e = sym_eig(&a, 50, 1e-13);
        let r = reconstruct(&e);
        assert!(a.max_abs_diff(&r) < 1e-4, "diff {}", a.max_abs_diff(&r));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(16, 3);
        let e = sym_eig(&a, 50, 1e-13);
        let vt_v = e.vectors.transpose().matmul(&e.vectors);
        assert!(vt_v.max_abs_diff(&Mat::eye(16)) < 1e-5);
    }

    #[test]
    fn eigen_equation_holds() {
        let a = random_symmetric(12, 11);
        let e = sym_eig(&a, 50, 1e-13);
        for k in 0..12 {
            let v: Vec<f32> = (0..12).map(|i| e.vectors.at(i, k)).collect();
            let av = a.matvec(&v);
            for i in 0..12 {
                let want = e.values[k] as f32 * v[i];
                assert!(
                    (av[i] - want).abs() < 1e-4,
                    "k={k} i={i}: {} vs {want}",
                    av[i]
                );
            }
        }
    }

    #[test]
    fn psd_gram_matrix_nonnegative_spectrum() {
        // Gram matrix of random vectors is PSD: eigenvalues >= -tiny.
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(10, 4, |_, _| rng.normal() as f32);
        let g = x.matmul_nt(&x);
        let e = sym_eig(&g, 50, 1e-13);
        assert!(e.values.iter().all(|&l| l > -1e-5), "{:?}", e.values);
        // Rank <= 4: at most 4 eigenvalues significantly above zero.
        assert_eq!(e.effective_rank(1e-6), 4);
    }

    #[test]
    fn effective_rank_thresholding() {
        let a = Mat::from_vec(3, 3, vec![1., 0., 0., 0., 1e-3, 0., 0., 0., 1e-9]);
        let e = sym_eig(&a, 30, 1e-14);
        assert_eq!(e.effective_rank(1e-6), 2);
        assert_eq!(e.effective_rank(1e-12), 3);
        assert_eq!(e.effective_rank(0.5), 1);
    }

    #[test]
    fn whitening_map_whitens() {
        // W = V Λ^{-1/2}  =>  Wᵀ A W = I on the kept subspace.
        let a = random_symmetric(8, 13);
        // Make PSD: A := AᵀA (via matmul with transpose).
        let a = a.transpose().matmul(&a);
        let e = sym_eig(&a, 60, 1e-13);
        let r = e.effective_rank(1e-10);
        let w = e.whitening_map(r);
        let wtaw = w.transpose().matmul(&a.matmul(&w));
        assert!(wtaw.max_abs_diff(&Mat::eye(r)) < 1e-3);
    }

    #[test]
    fn one_by_one() {
        let a = Mat::from_vec(1, 1, vec![4.0]);
        let e = sym_eig(&a, 10, 1e-14);
        assert_eq!(e.values.len(), 1);
        assert!((e.values[0] - 4.0).abs() < 1e-12);
    }
}
