//! Dense linear-algebra substrate.
//!
//! The paper's stage 1 needs batched GEMM (cuBLAS on their box) and a
//! symmetric eigendecomposition of the B×B landmark kernel matrix
//! (cuSOLVER `syevd`). Neither BLAS nor LAPACK is linkable offline, so this
//! module implements both from scratch: a cache-blocked row-major GEMM and
//! a cyclic-Jacobi eigensolver (chosen over QR iteration for robustness on
//! the near-singular kernel matrices the paper §4 warns about — Jacobi
//! degrades gracefully, and the paper itself rejects Cholesky for the same
//! reason; we still ship Cholesky for tests and comparison).

pub mod chol;
pub mod dense;
pub mod eigen;

pub use dense::Mat;
pub use eigen::SymEig;
