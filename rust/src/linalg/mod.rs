//! Dense linear-algebra substrate.
//!
//! The paper's stage 1 needs batched GEMM (cuBLAS on their box) and a
//! symmetric eigendecomposition of the B×B landmark kernel matrix
//! (cuSOLVER `syevd`). Neither BLAS nor LAPACK is linkable offline, so this
//! module implements both from scratch: a cache-blocked row-major GEMM and
//! a cyclic-Jacobi eigensolver (chosen over QR iteration for robustness on
//! the near-singular kernel matrices the paper §4 warns about — Jacobi
//! degrades gracefully, and the paper itself rejects Cholesky for the same
//! reason; we still ship Cholesky for tests and comparison).
//!
//! Invariants: the `_threads` GEMM variants are bit-identical to serial
//! for every thread count (row banding never reassociates a row's
//! arithmetic); the tournament eigensolver is deterministic per thread
//! count and cut over by matrix size only; eigenvalue ordering is total
//! even in the presence of NaN inputs (`total_cmp`).

pub mod chol;
pub mod dense;
pub mod eigen;

pub use dense::Mat;
pub use eigen::SymEig;
