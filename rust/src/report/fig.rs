//! ASCII log-scale bar rendering for figure-style bench output (the paper's
//! figures 2/3 are log-scale bar charts; this gives a terminal-native
//! approximation alongside the TSV export).

/// Render a horizontal log-scale bar for `value` seconds within
/// `[lo, hi]`, `width` characters wide.
pub fn log_bar(value: f64, lo: f64, hi: f64, width: usize) -> String {
    if !(value.is_finite()) || value <= 0.0 {
        return String::new();
    }
    let lo = lo.max(1e-9);
    let hi = hi.max(lo * 10.0);
    let t = ((value.ln() - lo.ln()) / (hi.ln() - lo.ln())).clamp(0.0, 1.0);
    let n = (t * width as f64).round() as usize;
    "█".repeat(n.max(1))
}

/// Render a labelled group of log-scale bars.
pub fn bar_chart(title: &str, entries: &[(String, f64)], width: usize) -> String {
    let mut out = format!("-- {title} (log scale) --\n");
    let lo = entries
        .iter()
        .map(|(_, v)| *v)
        .filter(|v| *v > 0.0)
        .fold(f64::MAX, f64::min);
    let hi = entries.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    let label_w = entries.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, v) in entries {
        out.push_str(&format!(
            "{label:<label_w$}  {:>10.4}s  {}\n",
            v,
            log_bar(*v, lo, hi, width)
        ));
    }
    out
}

/// Write a labelled bar chart into any byte sink — the figure-side
/// counterpart of [`crate::report::Table::write_to`]; benches hand it
/// stdout, tests a buffer.
pub fn write_bar_chart(
    w: &mut impl std::io::Write,
    title: &str,
    entries: &[(String, f64)],
    width: usize,
) -> std::io::Result<()> {
    w.write_all(bar_chart(title, entries, width).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_monotone_in_value() {
        let a = log_bar(0.01, 0.01, 100.0, 40).len();
        let b = log_bar(1.0, 0.01, 100.0, 40).len();
        let c = log_bar(100.0, 0.01, 100.0, 40).len();
        assert!(a <= b && b <= c);
        assert!(c >= 40 * 3); // "█" is 3 bytes
    }

    #[test]
    fn zero_and_nan_are_empty() {
        assert!(log_bar(0.0, 0.1, 1.0, 10).is_empty());
        assert!(log_bar(f64::NAN, 0.1, 1.0, 10).is_empty());
    }

    #[test]
    fn chart_contains_labels() {
        let s = bar_chart(
            "demo",
            &[("fast".into(), 0.01), ("slow".into(), 10.0)],
            20,
        );
        assert!(s.contains("fast"));
        assert!(s.contains("slow"));
        assert!(s.contains("log scale"));
    }

    #[test]
    fn sink_matches_string_render() {
        let entries = [("x".to_string(), 0.5)];
        let mut buf: Vec<u8> = Vec::new();
        write_bar_chart(&mut buf, "demo", &entries, 10).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), bar_chart("demo", &entries, 10));
    }
}
