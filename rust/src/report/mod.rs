//! Paper-style tables and figure data emission for the bench harness.
//!
//! Paper role: the paper reports its results as tables (table 1–3) and
//! stage-breakdown figures (figure 3); [`table`] renders the in-repo
//! equivalents for the CLI and benches (plus TSV export for artifacts),
//! and [`fig`] emits the data series the figure benches record.
//!
//! Invariant: rendering is purely a view — nothing in this module
//! computes or mutates results, so a table/figure can be regenerated
//! from the same run without perturbing it.

pub mod fig;
pub mod table;

pub use table::Table;
