//! Paper-style tables and figure data emission for the bench harness.

pub mod fig;
pub mod table;

pub use table::Table;
