//! Plain-text table rendering (paper tables 2/3) and TSV series emission
//! (figures 2/3) for the benchmark harness.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Format seconds the way the paper's tables do (3 significant-ish
    /// digits, comma grouping is skipped).
    pub fn secs(x: f64) -> String {
        if x >= 100.0 {
            format!("{x:.0}")
        } else if x >= 1.0 {
            format!("{x:.2}")
        } else {
            format!("{x:.3}")
        }
    }

    /// Format an error rate as percent.
    pub fn pct(x: f64) -> String {
        format!("{:.2}", x * 100.0)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$}  ", c, w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Render into any byte sink — the single choke point table output
    /// funnels through; [`Table::print`] hands it a locked stdout.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        writeln!(w, "{}", self.render())
    }

    /// Print to stdout. Tables are *results*, so they stay on stdout
    /// rather than going through the stderr logger.
    pub fn print(&self) {
        let _ = self.write_to(&mut std::io::stdout().lock());
    }

    /// Write the table as TSV (figure-data export for external plotting).
    pub fn write_tsv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut s = String::new();
        let _ = writeln!(s, "# {}", self.title);
        let _ = writeln!(s, "{}", self.header.join("\t"));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join("\t"));
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["solver", "time"]);
        t.row(&["LPD-SVM".into(), "1.23".into()]);
        t.row(&["ThunderSVM".into(), "456".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("LPD-SVM"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(Table::secs(1402.86), "1403");
        assert_eq!(Table::secs(89.86), "89.86");
        assert_eq!(Table::secs(0.123), "0.123");
        assert_eq!(Table::pct(0.1477), "14.77");
    }

    #[test]
    fn write_to_matches_render() {
        let mut t = Table::new("sink", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let mut buf: Vec<u8> = Vec::new();
        t.write_to(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), t.render() + "\n");
    }

    #[test]
    fn tsv_written() {
        let mut t = Table::new("fig", &["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("lpdsvm_table");
        let path = dir.join("fig.tsv");
        t.write_tsv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("x\ty"));
        assert!(content.contains("1\t2"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
